"""LSB-first buffered bit reader (paper §4.1, Fig 7).

Deflate packs data LSB-first within each byte (RFC 1951 §3.1.1); Huffman codes
are packed starting from the code's most-significant bit, so a peek() of the
raw LSB-first bits yields the code bits *reversed* — decode LUTs account for
that (see ``huffman.py``).

The paper's key observation (Fig 7) is that bit-reader bandwidth grows with
the number of bits per call, so callers should batch reads. This
implementation keeps a 64-bit-ish Python-int bit buffer refilled 8 bytes at a
time, giving a read cost that is amortized over many bits.
"""

from __future__ import annotations

from .errors import EndOfStream

_MASKS = [(1 << n) - 1 for n in range(65)]


class BitReader:
    """Reads LSB-first bit fields from a bytes-like buffer.

    The reader may be positioned at any absolute *bit* offset — the
    foundation of the speculative block finder, which must test candidate
    deflate headers at every bit position.
    """

    __slots__ = ("data", "n_bytes", "_byte_pos", "_buf", "_nbits")

    def __init__(self, data, start_bit: int = 0):
        # memoryview avoids copies when slicing refills.
        self.data = bytes(data) if not isinstance(data, (bytes, memoryview)) else data
        self.n_bytes = len(self.data)
        self._byte_pos = 0
        self._buf = 0
        self._nbits = 0
        if start_bit:
            self.seek(start_bit)

    # -- position ---------------------------------------------------------

    @property
    def bit_pos(self) -> int:
        """Absolute bit offset of the next bit to be read."""
        return self._byte_pos * 8 - self._nbits

    def seek(self, bit_offset: int) -> None:
        if bit_offset < 0:
            raise ValueError("negative bit offset")
        byte, bit = divmod(bit_offset, 8)
        self._byte_pos = byte
        self._buf = 0
        self._nbits = 0
        if bit:
            self._refill(bit)
            self._buf >>= bit
            self._nbits -= bit

    def bits_left(self) -> int:
        return self.n_bytes * 8 - self.bit_pos

    def eof(self) -> bool:
        return self.bit_pos >= self.n_bytes * 8

    # -- refill -----------------------------------------------------------

    def _refill(self, need: int) -> None:
        """Ensure at least ``need`` bits are buffered (pads at EOF)."""
        while self._nbits < need:
            take = min(8, self.n_bytes - self._byte_pos)
            if take <= 0:
                raise EndOfStream("bit reader exhausted")
            word = int.from_bytes(self.data[self._byte_pos : self._byte_pos + take], "little")
            self._buf |= word << self._nbits
            self._nbits += take * 8
            self._byte_pos += take

    # -- reads ------------------------------------------------------------

    def read(self, n: int) -> int:
        """Read ``n`` bits LSB-first; raises EndOfStream past the end."""
        if self._nbits < n:
            self._refill(n)
        val = self._buf & _MASKS[n]
        self._buf >>= n
        self._nbits -= n
        return val

    def peek(self, n: int) -> int:
        """Peek ``n`` bits without consuming; zero-padded at EOF.

        Zero padding (rather than raising) lets Huffman LUT decode peek a
        full max-length window near the end of the buffer; the subsequent
        ``skip`` detects actual overruns.
        """
        if self._nbits < n:
            try:
                self._refill(n)
            except EndOfStream:
                pass  # zero-padded peek at EOF
        return self._buf & _MASKS[n]

    def skip(self, n: int) -> None:
        if self._nbits < n:
            self._refill(n)  # raises EndOfStream on true overrun
        self._buf >>= n
        self._nbits -= n

    def align_to_byte(self) -> int:
        """Skip to the next byte boundary; returns number of bits skipped."""
        rem = self.bit_pos % 8
        if rem:
            self.skip(8 - rem)
            return 8 - rem
        return 0

    def read_bytes(self, n: int) -> bytes:
        """Read ``n`` byte-aligned bytes (fast path for stored blocks)."""
        if self.bit_pos % 8:
            raise ValueError("read_bytes requires byte alignment")
        start = self.bit_pos // 8
        if start + n > self.n_bytes:
            raise EndOfStream("read_bytes past end")
        out = bytes(self.data[start : start + n])
        # Drop buffered bits and jump.
        self._byte_pos = start + n
        self._buf = 0
        self._nbits = 0
        return out
