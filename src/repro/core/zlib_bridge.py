"""zlib delegation for index-backed decompression (paper §1.3, §3.3).

Once a seek point (bit offset + 32 KiB window) exists, decompression can be
delegated to zlib — "more than twice as fast as the two-stage decompression"
(paper §1.3). zlib can only start at byte boundaries, so the compressed
stream is re-aligned by a vectorized bit shift first; the window is primed
via ``zdict`` on a raw-deflate decompressobj.
"""

from __future__ import annotations

import zlib
from typing import Optional

import numpy as np

from .errors import DeflateError


def shift_bitstream(data, bit_offset: int, max_bytes: Optional[int] = None) -> bytes:
    """Re-pack ``data`` starting at ``bit_offset`` onto a byte boundary.

    Vectorized: each output byte pulls ``8-k`` low bits from one input byte
    and ``k`` bits from the next (deflate is LSB-first, so the shift moves
    toward the LSB).
    """
    byte, bit = divmod(bit_offset, 8)
    if max_bytes is None:
        end = len(data)
    else:
        end = min(len(data), byte + max_bytes + 1)
    at_eof = end >= len(data)
    if bit == 0:
        hi_end = end if max_bytes is None else min(byte + max_bytes, len(data))
        return bytes(data[byte:hi_end])
    arr = np.frombuffer(data, dtype=np.uint8, count=end - byte, offset=byte)
    if arr.shape[0] == 0:
        return b""
    lo = arr >> np.uint8(bit)
    hi = np.empty_like(arr)
    hi[:-1] = arr[1:] << np.uint8(8 - bit)
    hi[-1] = 0
    out = lo | hi
    if not at_eof:
        # The final byte is only partially determined without the next input
        # byte — emit fully-formed bytes only; the caller advances by the
        # returned length and re-reads the boundary byte.
        out = out[:-1]
    return out.tobytes()


def zlib_inflate_at(
    data,
    bit_offset: int,
    window: bytes,
    out_size: int,
    *,
    feed_bytes: int = 1 << 16,
    max_input_bytes: Optional[int] = None,
) -> bytes:
    """Inflate exactly ``out_size`` bytes starting at ``bit_offset``.

    The stream is fed incrementally so only O(out_size / ratio) input is
    bit-shifted, not the whole file tail.

    ``max_input_bytes`` must bound the chunk's compressed span when known:
    zlib eagerly parses the *next* block header even with no output space
    remaining, and a stored-block header does not survive the bit-shift
    realignment — truncating the input at the chunk boundary keeps zlib
    waiting for input instead of erroring on the successor's header.
    """
    if out_size == 0:
        return b""
    d = zlib.decompressobj(wbits=-zlib.MAX_WBITS, zdict=window)
    out = []
    produced = 0
    pos = bit_offset
    total_bits = len(data) * 8
    if max_input_bytes is not None:
        total_bits = min(total_bits, bit_offset + max_input_bytes * 8)
    while produced < out_size:
        if pos >= total_bits:
            raise DeflateError("compressed stream exhausted before chunk end")
        piece = shift_bitstream(data, pos, max_bytes=min(feed_bytes, (total_bits - pos) // 8 + 1))
        if max_input_bytes is not None and pos + len(piece) * 8 > total_bits:
            piece = piece[: max(1, (total_bits - pos) // 8)]
        pos += len(piece) * 8
        try:
            chunk = d.decompress(d.unconsumed_tail + piece, out_size - produced)
        except zlib.error as exc:
            raise DeflateError("zlib delegation failed: %s" % exc) from exc
        out.append(chunk)
        produced += len(chunk)
        if d.eof:
            # End of this deflate stream (gzip member boundary). A chunk can
            # span members; the caller's seek points are built so member
            # boundaries coincide with chunk boundaries or interior block
            # boundaries — restart a fresh raw stream after the footer is
            # not handled here; chunks with interior member ends use the
            # custom decoder instead.
            break
    result = b"".join(out)
    if len(result) < out_size:
        raise DeflateError(
            "zlib delegation produced %d of %d bytes" % (len(result), out_size)
        )
    return result
