"""Thread-safe LRU caches (paper §3.2).

The chunk fetcher uses two separate caches so prefetch traffic cannot evict
explicitly accessed chunks ("to avoid cache pollution", paper §3.2): a small
*access cache* (size 1 for plain sequential decompression) and a *prefetch
cache* sized at twice the parallelism. False-positive chunk results enter
the prefetch cache under a wrong offset key, are never requested, and age
out — that eviction path is what makes the whole architecture robust.

The class is written so that shared-resource variants can subclass it
(`service/cache_pool.py`): every mutation goes through a ``*_locked`` core
method that reports exactly what changed, public methods re-dispatch through
those cores under one lock acquisition, and the lock is re-entrant.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Dict, Hashable, List, Optional, Tuple


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    insertions: int = 0
    evictions: int = 0

    def as_dict(self) -> Dict[str, int]:
        return {k: int(getattr(self, k)) for k in self.__dataclass_fields__}

    def copy(self) -> "CacheStats":
        return CacheStats(**self.as_dict())

    def merge(self, *others: "CacheStats") -> "CacheStats":
        """New CacheStats summing ``self`` with ``others`` (for fleet-wide
        aggregation across many caches; does not mutate any operand)."""
        out = self.copy()
        for other in others:
            if isinstance(other, dict):
                other = CacheStats(**{k: int(other.get(k, 0)) for k in out.__dataclass_fields__})
            out.hits += other.hits
            out.misses += other.misses
            out.insertions += other.insertions
            out.evictions += other.evictions
        return out

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class LRUCache:
    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._data: "OrderedDict[Hashable, Any]" = OrderedDict()
        # Re-entrant so subclasses can wrap a core op + bookkeeping in one
        # critical section without self-deadlocking.
        self._lock = threading.RLock()
        self.stats = CacheStats()

    # -- core mutations (hold the lock; report what changed) ---------------

    def _get_locked(
        self, key: Hashable, record_miss: bool = True
    ) -> Tuple[bool, Optional[Any]]:
        if key in self._data:
            self._data.move_to_end(key)
            self.stats.hits += 1
            return True, self._data[key]
        if record_miss:
            self.stats.misses += 1
        return False, None

    def _insert_locked(
        self, key: Hashable, value: Any
    ) -> Tuple[Optional[Any], List[Tuple[Hashable, Any]]]:
        """Returns (replaced_value_or_None, [(evicted_key, evicted_value)])."""
        if key in self._data:
            replaced = self._data[key]
            self._data.move_to_end(key)
            self._data[key] = value
            return replaced, []
        self._data[key] = value
        self.stats.insertions += 1
        evicted: List[Tuple[Hashable, Any]] = []
        while len(self._data) > self.capacity:
            evicted.append(self._data.popitem(last=False))
            self.stats.evictions += 1
        return None, evicted

    def _pop_locked(self, key: Hashable) -> Optional[Any]:
        return self._data.pop(key, None)

    # -- public interface ---------------------------------------------------

    def get(self, key: Hashable) -> Optional[Any]:
        return self.lookup(key)

    def lookup(self, key: Hashable, *, record_miss: bool = True) -> Optional[Any]:
        """get() that optionally skips miss accounting.

        One *logical* lookup that probes several caches in sequence (access
        then prefetch, `GzipChunkFetcher._cache_lookup`) must record exactly
        one hit or one miss fleet-wide; probing the first cache with
        ``record_miss=False`` lets the later cache own the miss.
        """
        with self._lock:
            _, val = self._get_locked(key, record_miss=record_miss)
            return val

    def peek(self, key: Hashable) -> Optional[Any]:
        """Get without touching LRU order or stats."""
        with self._lock:
            return self._data.get(key)

    def insert(self, key: Hashable, value: Any) -> None:
        with self._lock:
            self._insert_locked(key, value)

    def insert_hinted(
        self, key: Hashable, value: Any, *, recompute_cost: Optional[int] = None
    ) -> None:
        """insert() carrying an estimated cost (bytes of work) to recompute
        the value if evicted. The plain LRU ignores it; pool-backed caches
        (service/cache_pool.py) use it for cost-aware victim selection —
        cheap zlib-delegable chunks go before expensive marker-mode ones.
        """
        del recompute_cost
        self.insert(key, value)

    def pop(self, key: Hashable) -> Optional[Any]:
        with self._lock:
            return self._pop_locked(key)

    def __contains__(self, key: Hashable) -> bool:
        with self._lock:
            return key in self._data

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def keys(self):
        with self._lock:
            return list(self._data.keys())

    def clear(self) -> None:
        with self._lock:
            self._data.clear()

    def snapshot(self) -> Dict[str, Any]:
        """Atomic view of (stats, occupancy) — one lock acquisition, so the
        counters and the length are mutually consistent even while fetcher
        threads keep hitting the cache."""
        with self._lock:
            return {
                "stats": self.stats.copy(),
                "len": len(self._data),
                "capacity": self.capacity,
            }
