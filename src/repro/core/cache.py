"""Thread-safe LRU caches (paper §3.2).

The chunk fetcher uses two separate caches so prefetch traffic cannot evict
explicitly accessed chunks ("to avoid cache pollution", paper §3.2): a small
*access cache* (size 1 for plain sequential decompression) and a *prefetch
cache* sized at twice the parallelism. False-positive chunk results enter
the prefetch cache under a wrong offset key, are never requested, and age
out — that eviction path is what makes the whole architecture robust.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, Hashable, Optional


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    insertions: int = 0
    evictions: int = 0

    def as_dict(self) -> Dict[str, int]:
        return {k: int(getattr(self, k)) for k in self.__dataclass_fields__}


class LRUCache:
    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._data: "OrderedDict[Hashable, Any]" = OrderedDict()
        self._lock = threading.Lock()
        self.stats = CacheStats()

    def get(self, key: Hashable) -> Optional[Any]:
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self.stats.hits += 1
                return self._data[key]
            self.stats.misses += 1
            return None

    def peek(self, key: Hashable) -> Optional[Any]:
        """Get without touching LRU order or stats."""
        with self._lock:
            return self._data.get(key)

    def insert(self, key: Hashable, value: Any) -> None:
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self._data[key] = value
                return
            self._data[key] = value
            self.stats.insertions += 1
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)
                self.stats.evictions += 1

    def pop(self, key: Hashable) -> Optional[Any]:
        with self._lock:
            return self._data.pop(key, None)

    def __contains__(self, key: Hashable) -> bool:
        with self._lock:
            return key in self._data

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def keys(self):
        with self._lock:
            return list(self._data.keys())

    def clear(self) -> None:
        with self._lock:
            self._data.clear()
