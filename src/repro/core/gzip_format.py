"""gzip member framing (RFC 1952) and BGZF detection (paper §3.4.4, Fig 1).

A gzip *file* is a concatenation of gzip *members*; each member wraps one raw
deflate stream with a header (magic, flags, optional extra/name/comment/hcrc)
and a footer (CRC32 + ISIZE). BGZF (the Blocked GNU Zip Format used by
htslib/bgzip) is a gzip subset whose FEXTRA field carries the compressed
member size, making member boundaries — and hence trivially parallel
decompression — directly available (the GzipChunkFetcher has a fast path for
it, mirroring rapidgzip).
"""

from __future__ import annotations

import struct
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from .bitreader import BitReader
from .errors import EndOfStream, GzipHeaderError

MAGIC1, MAGIC2 = 0x1F, 0x8B
CM_DEFLATE = 8

FTEXT = 1
FHCRC = 2
FEXTRA = 4
FNAME = 8
FCOMMENT = 16
FRESERVED = 0xE0


@dataclass
class GzipHeader:
    header_bits: int  # size of the header in bits (always a multiple of 8)
    mtime: int = 0
    os: int = 255
    xfl: int = 0
    name: Optional[bytes] = None
    comment: Optional[bytes] = None
    extra: Optional[bytes] = None
    is_bgzf: bool = False
    bgzf_block_size: Optional[int] = None  # BSIZE+1: total member size in bytes


@dataclass
class GzipFooter:
    crc32: int
    isize: int


def parse_gzip_header(br: BitReader) -> GzipHeader:
    """Parse a gzip member header at the reader's (byte-aligned) position."""
    start = br.bit_pos
    if start % 8:
        raise GzipHeaderError("gzip header must be byte-aligned")
    try:
        id1 = br.read(8)
        id2 = br.read(8)
        if id1 != MAGIC1 or id2 != MAGIC2:
            raise GzipHeaderError("bad gzip magic %02x%02x" % (id1, id2))
        cm = br.read(8)
        if cm != CM_DEFLATE:
            raise GzipHeaderError("unsupported compression method %d" % cm)
        flg = br.read(8)
        if flg & FRESERVED:
            raise GzipHeaderError("reserved FLG bits set")
        mtime = br.read(32)
        xfl = br.read(8)
        os_ = br.read(8)

        hdr = GzipHeader(header_bits=0, mtime=mtime, os=os_, xfl=xfl)
        if flg & FEXTRA:
            xlen = br.read(16)
            extra = br.read_bytes(xlen) if xlen else b""
            hdr.extra = extra
            _parse_bgzf_extra(hdr, extra)
        if flg & FNAME:
            hdr.name = _read_zero_terminated(br)
        if flg & FCOMMENT:
            hdr.comment = _read_zero_terminated(br)
        if flg & FHCRC:
            br.read(16)  # header CRC16 — parsed, not verified (as rapidgzip)
    except EndOfStream as exc:
        raise GzipHeaderError("truncated gzip header") from exc
    hdr.header_bits = br.bit_pos - start
    return hdr


def _read_zero_terminated(br: BitReader) -> bytes:
    out = bytearray()
    while True:
        b = br.read(8)
        if b == 0:
            return bytes(out)
        out.append(b)
        if len(out) > 1 << 16:
            raise GzipHeaderError("unterminated gzip header string")


def _parse_bgzf_extra(hdr: GzipHeader, extra: bytes) -> None:
    """Scan FEXTRA subfields for the BGZF 'BC' marker (paper §3.4.4)."""
    pos = 0
    while pos + 4 <= len(extra):
        si1, si2, slen = extra[pos], extra[pos + 1], struct.unpack_from("<H", extra, pos + 2)[0]
        if si1 == 66 and si2 == 67 and slen == 2 and pos + 6 <= len(extra):  # 'B','C'
            bsize = struct.unpack_from("<H", extra, pos + 4)[0]
            hdr.is_bgzf = True
            hdr.bgzf_block_size = bsize + 1
            return
        pos += 4 + slen


def parse_gzip_footer(br: BitReader) -> GzipFooter:
    """Parse the 8-byte CRC32+ISIZE footer at a byte-aligned position."""
    if br.bit_pos % 8:
        raise GzipHeaderError("gzip footer must be byte-aligned")
    crc = br.read(32)
    isize = br.read(32)
    return GzipFooter(crc, isize)


# ---------------------------------------------------------------------------
# Whole-file helpers
# ---------------------------------------------------------------------------

def parse_first_header(data) -> GzipHeader:
    return parse_gzip_header(BitReader(data))


def detect_bgzf(data) -> bool:
    """True if the file starts with a BGZF member (bgzip fast path)."""
    try:
        return parse_first_header(data).is_bgzf
    except GzipHeaderError:
        return False


def scan_bgzf_members(reader, *, max_members: Optional[int] = None) -> List[Tuple[int, int]]:
    """Walk BGZF member headers via the BSIZE metadata.

    Returns [(member_byte_offset, member_byte_size), ...]. This is the
    "trivially parallel" path: no speculation, no two-stage decode needed.
    """
    members: List[Tuple[int, int]] = []
    offset = 0
    size = reader.size()
    while offset < size:
        head = reader.pread(offset, 1 << 12)
        if len(head) < 18:
            break
        hdr = parse_gzip_header(BitReader(head))
        if not hdr.is_bgzf or not hdr.bgzf_block_size:
            raise GzipHeaderError("non-BGZF member in BGZF scan at offset %d" % offset)
        members.append((offset, hdr.bgzf_block_size))
        offset += hdr.bgzf_block_size
        if max_members is not None and len(members) >= max_members:
            break
    return members
