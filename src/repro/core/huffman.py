"""Canonical Huffman code construction and LUT decoding (RFC 1951 §3.2.2).

Decode LUTs map a ``max_len``-bit *LSB-first* peek window directly to
``(symbol, code_length)``; because deflate packs Huffman codes MSB-first into
an otherwise LSB-first stream, each code's bits must be reversed when filling
the table.

Validity semantics (paper §3.4.2, Fig 6):
  * *invalid*   — over-subscribed: more codes than the binary tree permits.
  * *inefficient* — incomplete: unused leaves remain.
The block finder rejects both ("valid and efficient"); the actual decoder is
lenient where RFC/zlib are (an incomplete *distance* code with <=1 codes is
legal, and an unused-entry lookup only errors when actually consumed).
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from .errors import DeflateError

# Sentinel for LUT entries not covered by any code (incomplete codes).
INVALID_ENTRY = np.int32(-1)

#: code length order for the precode (RFC 1951 §3.2.7)
PRECODE_ORDER = (16, 17, 18, 0, 8, 7, 9, 6, 10, 5, 11, 4, 12, 3, 13, 2, 14, 1, 15)

MAX_PRECODE_LEN = 7
MAX_CODE_LEN = 15


def reverse_bits(value: int, width: int) -> int:
    out = 0
    for _ in range(width):
        out = (out << 1) | (value & 1)
        value >>= 1
    return out


def check_code_lengths(lengths: Sequence[int], max_len: int) -> int:
    """Kraft-sum check. Returns:

    0  -> valid and complete ("efficient")
    1  -> incomplete (unused leaves; paper calls these "non-optimal")
    2  -> over-subscribed (invalid)
    3  -> empty (no symbols at all)
    """
    total = 0
    unit = 1 << max_len
    n_codes = 0
    for l in lengths:
        if l:
            total += unit >> l
            n_codes += 1
    if n_codes == 0:
        return 3
    if total > unit:
        return 2
    if total < unit:
        return 1
    return 0


class HuffmanLUT:
    """Flat decode LUT: ``table[peek(max_len)] -> (length << 16) | symbol``."""

    __slots__ = ("table", "max_len", "n_symbols")

    def __init__(self, table: np.ndarray, max_len: int, n_symbols: int):
        self.table = table
        self.max_len = max_len
        self.n_symbols = n_symbols

    @staticmethod
    def from_lengths(
        lengths: Sequence[int],
        *,
        strict: bool = False,
        allow_incomplete: bool = False,
    ) -> "HuffmanLUT":
        """Build from per-symbol code lengths.

        strict=True          -> reject over-subscribed AND incomplete codes
                                (block-finder semantics, paper Fig 6).
        allow_incomplete     -> permit incomplete codes; unfilled entries decode
                                to INVALID and raise only if consumed (zlib
                                distance-code semantics).
        """
        lengths = np.asarray(lengths, dtype=np.int64)
        status = check_code_lengths(lengths, MAX_CODE_LEN)
        if status == 2:
            raise DeflateError("over-subscribed Huffman code")
        if status == 3:
            raise DeflateError("empty Huffman code")
        if status == 1 and (strict or not allow_incomplete):
            raise DeflateError("incomplete Huffman code")

        max_len = int(lengths.max())
        size = 1 << max_len

        # Canonical code assignment: codes ordered by (length, symbol).
        bl_count = np.bincount(lengths, minlength=MAX_CODE_LEN + 1)
        bl_count[0] = 0
        next_code = np.zeros(MAX_CODE_LEN + 2, dtype=np.int64)
        code = 0
        for l in range(1, max_len + 1):
            code = (code + bl_count[l - 1]) << 1
            next_code[l] = code

        table = np.full(size, INVALID_ENTRY, dtype=np.int32)
        for sym, l in enumerate(lengths):
            if l == 0:
                continue
            c = int(next_code[l])
            next_code[l] += 1
            rev = reverse_bits(c, int(l))
            entry = (int(l) << 16) | sym
            # All peek windows whose low ``l`` bits equal the reversed code.
            table[rev :: 1 << int(l)] = entry
        return HuffmanLUT(table, max_len, int(len(lengths)))

    def decode(self, bitreader) -> int:
        """Decode one symbol from the bit reader."""
        entry = int(self.table[bitreader.peek(self.max_len)])
        if entry < 0:
            raise DeflateError("invalid Huffman bit pattern (unused code)")
        bitreader.skip(entry >> 16)
        return entry & 0xFFFF


# ---------------------------------------------------------------------------
# Fixed (type-1) deflate codes, RFC 1951 §3.2.6 — built once at import time.
# ---------------------------------------------------------------------------

def _fixed_literal_lengths() -> np.ndarray:
    lengths = np.empty(288, dtype=np.int64)
    lengths[0:144] = 8
    lengths[144:256] = 9
    lengths[256:280] = 7
    lengths[280:288] = 8
    return lengths


FIXED_LITERAL_LUT = HuffmanLUT.from_lengths(_fixed_literal_lengths())
# The fixed distance "code" is 5-bit flat; 30/31 are invalid if consumed.
FIXED_DISTANCE_LUT = HuffmanLUT.from_lengths(np.full(32, 5, dtype=np.int64))


# ---------------------------------------------------------------------------
# Length / distance extra-bit tables (RFC 1951 §3.2.5) as numpy arrays so the
# decoder can index them without branching.
# ---------------------------------------------------------------------------

LENGTH_BASE = np.array(
    [3, 4, 5, 6, 7, 8, 9, 10, 11, 13, 15, 17, 19, 23, 27, 31,
     35, 43, 51, 59, 67, 83, 99, 115, 131, 163, 195, 227, 258],
    dtype=np.int64,
)
LENGTH_EXTRA = np.array(
    [0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2,
     3, 3, 3, 3, 4, 4, 4, 4, 5, 5, 5, 5, 0],
    dtype=np.int64,
)
DISTANCE_BASE = np.array(
    [1, 2, 3, 4, 5, 7, 9, 13, 17, 25, 33, 49, 65, 97, 129, 193,
     257, 385, 513, 769, 1025, 1537, 2049, 3073, 4097, 6145,
     8193, 12289, 16385, 24577],
    dtype=np.int64,
)
DISTANCE_EXTRA = np.array(
    [0, 0, 0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6,
     7, 7, 8, 8, 9, 9, 10, 10, 11, 11, 12, 12, 13, 13],
    dtype=np.int64,
)


def decode_code_lengths(bitreader, precode_lut: HuffmanLUT, n_total: int, *, strict: bool = False) -> np.ndarray:
    """Decode ``n_total`` literal+distance code lengths using the precode.

    Handles repeat codes 16/17/18. ``strict`` is the block-finder mode: any
    structural violation (repeat at start, overrun) raises immediately —
    paper Table 1 row "Invalid Precode-encoded data".
    """
    lengths = np.zeros(n_total, dtype=np.int64)
    i = 0
    prev = -1
    while i < n_total:
        sym = precode_lut.decode(bitreader)
        if sym < 16:
            lengths[i] = sym
            prev = sym
            i += 1
        elif sym == 16:
            if prev < 0:
                raise DeflateError("repeat code with no previous length")
            count = 3 + bitreader.read(2)
            if i + count > n_total:
                raise DeflateError("repeat overruns code-length table")
            lengths[i : i + count] = prev
            i += count
        elif sym == 17:
            count = 3 + bitreader.read(3)
            if i + count > n_total:
                raise DeflateError("zero-repeat overruns code-length table")
            i += count
            prev = 0
        else:  # 18
            count = 11 + bitreader.read(7)
            if i + count > n_total:
                raise DeflateError("zero-repeat overruns code-length table")
            i += count
            prev = 0
    return lengths
