"""Prefetching strategies (paper §3.2).

The default is the paper's ad-hoc strategy, "comparable to an exponentially
incremented adaptive asynchronous multi-stream prefetcher" (AMP, Gill &
Bathen FAST'07): it operates on *chunk indexes*, returns the full prefetch
degree on the first access of a stream so cold-start decompression is fully
parallel, tracks multiple concurrent sequential streams (the ratarmount
use-case: several files of one TAR read at once), and ramps the degree
exponentially as a stream proves itself. It deliberately does not remember
what it already prefetched — the fetcher filters cached/in-flight chunks.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List


class PrefetchStrategy:
    def on_access(self, index: int) -> List[int]:
        raise NotImplementedError


class NoPrefetch(PrefetchStrategy):
    def on_access(self, index: int) -> List[int]:
        return []


@dataclass
class _Stream:
    last_index: int
    hits: int


class AdaptivePrefetchStrategy(PrefetchStrategy):
    """Exponential, adaptive, multi-stream (paper §3.2 default)."""

    def __init__(self, degree: int, *, max_streams: int = 16, cold_start_full: bool = True):
        if degree < 0:
            raise ValueError("degree must be >= 0")
        self.degree = degree
        self.max_streams = max_streams
        self.cold_start_full = cold_start_full
        self._streams: Dict[int, _Stream] = {}  # keyed by stream id (insertion order)
        self._next_stream_id = 0

    def _find_stream(self, index: int):
        for sid, s in self._streams.items():
            # Tolerate small gaps/out-of-order completion within a stream.
            if 0 <= index - s.last_index <= 2:
                return sid, s
        return None, None

    def on_access(self, index: int) -> List[int]:
        if self.degree == 0:
            return []
        sid, stream = self._find_stream(index)
        if stream is None:
            # New stream: prefetch the full degree immediately so the thread
            # pool saturates on first access (paper: "returns the full degree
            # of prefetch for the initial access").
            if len(self._streams) >= self.max_streams:
                oldest = next(iter(self._streams))
                del self._streams[oldest]
            self._streams[self._next_stream_id] = _Stream(index, 1)
            self._next_stream_id += 1
            width = self.degree if self.cold_start_full else 2
        else:
            stream.hits += 1
            stream.last_index = max(stream.last_index, index)
            # Exponential ramp: 2, 4, 8, ... capped at the full degree.
            width = min(self.degree, 1 << min(stream.hits, 16))
        return [index + 1 + k for k in range(width)]


class BackwardPrefetchStrategy(PrefetchStrategy):
    """Prefetch behind the access point (reverse sequential scans)."""

    def __init__(self, degree: int):
        self.degree = degree

    def on_access(self, index: int) -> List[int]:
        return [index - 1 - k for k in range(self.degree) if index - 1 - k >= 0]
