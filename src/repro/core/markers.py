"""Stage-2 marker replacement and window propagation (paper §2.2 step 3).

The entire stage reduces to one gather through a 33 024-entry table:
``table = [0..255] ++ window`` and ``out[i] = table[sym[i]]`` — identity for
resolved literals, window lookup for markers. This formulation is shared
with the Pallas TPU kernel (``kernels/marker_replace.py``): the table fits
comfortably in VMEM and the gather streams at memory bandwidth.

Window *propagation* (computing the successor chunk's 32 KiB window) only
needs the replacement applied to the final 32 KiB of a chunk — the paper's
Amdahl mitigation: the sequential critical path per chunk is O(32 KiB),
while full-chunk replacement runs in parallel on the thread pool.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from .deflate import MARKER_BASE, WINDOW_SIZE


def full_window(window: Optional[bytes]) -> np.ndarray:
    """Left-pad a (possibly short) window to exactly WINDOW_SIZE bytes."""
    arr = np.zeros(WINDOW_SIZE, dtype=np.uint8)
    if window:
        w = np.frombuffer(window, dtype=np.uint8)[-WINDOW_SIZE:]
        arr[WINDOW_SIZE - w.shape[0] :] = w
    return arr


def replacement_table(window: Optional[bytes]) -> np.ndarray:
    """256 identity entries followed by the 32 KiB window."""
    table = np.empty(MARKER_BASE + WINDOW_SIZE, dtype=np.uint8)
    table[:MARKER_BASE] = np.arange(MARKER_BASE, dtype=np.uint8)
    table[MARKER_BASE:] = full_window(window)
    return table


def replace_markers(symbols: np.ndarray, window: Optional[bytes]) -> np.ndarray:
    """Resolve a uint16 intermediate chunk into uint8 bytes."""
    if symbols.dtype == np.uint8:
        return symbols
    return replacement_table(window)[symbols]


def replace_markers_segment(
    symbols: np.ndarray, table: np.ndarray, start: int, end: int
) -> np.ndarray:
    """Resolve one chunk segment (unit of thread-pool parallelism)."""
    return table[symbols[start:end]]


def propagate_window(
    symbols: np.ndarray,
    prev_window: Optional[bytes],
    *,
    first_marker: int = 0,
    last_marker: Optional[int] = None,
) -> bytes:
    """Next chunk's window from this chunk's tail (sequential critical path).

    Only the final WINDOW_SIZE symbols are resolved; if the chunk is shorter
    than the window the previous window fills the gap.
    """
    n = symbols.shape[0]
    take = min(n, WINDOW_SIZE)
    tail = symbols[n - take :]
    if symbols.dtype == np.uint16:
        tail = replacement_table(prev_window)[tail]
    if take >= WINDOW_SIZE:
        return tail.tobytes()
    prev = full_window(prev_window)
    return np.concatenate([prev[take:], tail]).tobytes()
