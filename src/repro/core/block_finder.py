"""Deflate block finders (paper §3.4, Tables 1 & 2).

The finder returns *candidate* bit offsets of Dynamic or Non-Compressed
deflate blocks. It may return false positives (unavoidable from an arbitrary
offset — paper §3.4) and need not find every block; the cache-and-prefetch
architecture absorbs both error modes.

Three Dynamic-Block-finder implementations are provided, mirroring the
paper's Table 2 comparison ladder:

  * ``find_dynamic_trial``   — trial header parse at every bit offset
                                ("DBF custom deflate").
  * ``find_dynamic_skiplut`` — sequential walk with the 14-bit skip-LUT
                                ("DBF skip-LUT").
  * ``find_dynamic_vectorized`` — the rapidgzip-JAX finder: every bit offset
                                in a batch is checked *simultaneously* with
                                numpy vector ops (final/type/HLIT), then the
                                precode Kraft check runs bit-packed over the
                                surviving offsets ("DBF rapidgzip"; this is
                                also the algorithm the Pallas kernel
                                ``kernels/precode_check.py`` implements for
                                the TPU VPU).

The check cascade is the paper's §3.4.2 order:
  (1) final-block bit == 0           (2) block type == 0b01 (dynamic)
  (3) HLIT not in {30, 31}           (4) precode histogram valid & complete
  (5) precode-decoded CLs valid      (6) distance code valid & complete
  (7) literal code valid & complete

Non-Compressed-Block candidates are canonicalized to bit offset ``8*p - 3``
(p = byte offset of the LEN field) because the zero padding makes the true
start ambiguous (paper §3.4.1); ``deflate`` records stop offsets with the
same canonicalization so cache keys match.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, List, Optional

import numpy as np

from .bitreader import BitReader
from .deflate import canonical_stored_offset, read_dynamic_header
from .errors import DeflateError, EndOfStream

# -- layout constants (RFC 1951 dynamic header) ------------------------------
_HLIT_AT = 3  # 5 bits
_HDIST_AT = 8  # 5 bits
_HCLEN_AT = 13  # 4 bits
_PRECODE_AT = 17  # (HCLEN+4) x 3 bits
_MAX_PRECODE_BITS = 19 * 3
_HEADER_PROBE_BITS = _PRECODE_AT + _MAX_PRECODE_BITS  # 74


# ---------------------------------------------------------------------------
# Bit-plane helpers
# ---------------------------------------------------------------------------

def _bit_array(data, start_byte: int, n_bytes: int) -> np.ndarray:
    """LSB-first bit plane of data[start_byte : start_byte+n_bytes]."""
    buf = np.frombuffer(data, dtype=np.uint8, count=min(n_bytes, len(data) - start_byte), offset=start_byte)
    return np.unpackbits(buf, bitorder="little")


def _field(bits: np.ndarray, n_offsets: int, at: int, width: int) -> np.ndarray:
    """value[i] = LSB-first ``width``-bit field at bit offset i+at, for all i."""
    out = bits[at : at + n_offsets].astype(np.uint32)
    for j in range(1, width):
        out |= bits[at + j : at + j + n_offsets].astype(np.uint32) << j
    return out


# ---------------------------------------------------------------------------
# Vectorized Dynamic Block finder (the production finder)
# ---------------------------------------------------------------------------

@dataclass
class FilterStats:
    """Per-stage rejection counters — reproduces paper Table 1."""

    tested: int = 0
    invalid_final: int = 0
    invalid_type: int = 0
    invalid_hlit: int = 0  # paper: "Invalid Precode size"
    invalid_precode_histogram: int = 0  # invalid + non-optimal precode code
    invalid_precode_data: int = 0
    invalid_distance: int = 0
    invalid_literal: int = 0
    valid: int = 0

    def as_dict(self) -> dict:
        return {k: int(getattr(self, k)) for k in self.__dataclass_fields__}


def _precode_kraft_mask(bits: np.ndarray, cand: np.ndarray) -> np.ndarray:
    """Vectorized precode histogram check for candidate offsets ``cand``.

    Gathers the 19 3-bit precode code lengths per candidate, builds the
    5-bit-packed frequency histogram (the paper's bit-level-parallel
    histogram: all 8 frequencies live in one 64-bit word) and applies the
    Kraft-completeness test: sum(count[l] << (7-l)) == 128.
    """
    hclen = (
        bits[cand + _HCLEN_AT].astype(np.uint32)
        | (bits[cand + _HCLEN_AT + 1].astype(np.uint32) << 1)
        | (bits[cand + _HCLEN_AT + 2].astype(np.uint32) << 2)
        | (bits[cand + _HCLEN_AT + 3].astype(np.uint32) << 3)
    )
    n_codes = hclen + 4

    # Packed histogram: bits [5l, 5l+5) hold the count of code length l.
    histo = np.zeros(cand.shape[0], dtype=np.uint64)
    kraft = np.zeros(cand.shape[0], dtype=np.uint32)
    for k in range(19):
        base = cand + (_PRECODE_AT + 3 * k)
        cl = (
            bits[base].astype(np.uint32)
            | (bits[base + 1].astype(np.uint32) << 1)
            | (bits[base + 2].astype(np.uint32) << 2)
        )
        active = (k < n_codes) & (cl > 0)
        histo += (active.astype(np.uint64)) << (np.uint64(5) * cl.astype(np.uint64))
        kraft += np.where(active, (128 >> cl).astype(np.uint32), 0)

    # Kraft equality <=> a valid AND complete ("efficient") code exists.
    del histo  # retained for parity with the packed-word formulation
    return kraft == 128


def scan_dynamic_candidates(
    data,
    start_bit: int,
    end_bit: int,
    *,
    batch_bits: int = 1 << 19,
    stats: Optional[FilterStats] = None,
    full_validation: bool = True,
) -> Iterator[int]:
    """Yield Dynamic-Block candidate bit offsets in [start_bit, end_bit).

    Lazy/batched: in the common case the caller confirms the first candidate
    (by decompressing the chunk) and never pulls more, so only the first
    batch is ever scanned.
    """
    total_bits = len(data) * 8
    end_bit = min(end_bit, total_bits - _HEADER_PROBE_BITS)
    pos = start_bit
    while pos < end_bit:
        batch_end = min(pos + batch_bits, end_bit)
        n = batch_end - pos
        # Load bits with margin for the header probe.
        first_byte = pos // 8
        last_byte = min((batch_end + _HEADER_PROBE_BITS) // 8 + 1, len(data))
        bits = _bit_array(data, first_byte, last_byte - first_byte)
        rel = pos - first_byte * 8

        b0 = bits[rel : rel + n]
        b1 = bits[rel + 1 : rel + 1 + n]
        b2 = bits[rel + 2 : rel + 2 + n]
        # (1) final == 0, (2) type == 0b01 (stream order: 0 then 1).
        mask = (b0 == 0) & (b1 == 0) & (b2 == 1)
        if stats is not None:
            stats.tested += n
            nf = int(np.count_nonzero(b0))
            stats.invalid_final += nf
            nt = int(np.count_nonzero((b0 == 0) & ~((b1 == 0) & (b2 == 1))))
            stats.invalid_type += nt
        # (3) HLIT must encode <= 286 literal codes.
        hlit = _field(bits[rel:], n, _HLIT_AT, 5)
        bad_hlit = hlit >= 30
        if stats is not None:
            stats.invalid_hlit += int(np.count_nonzero(mask & bad_hlit))
        mask &= ~bad_hlit

        cand = np.nonzero(mask)[0].astype(np.int64) + rel
        if cand.shape[0]:
            # (4) precode histogram Kraft check, bit-packed & vectorized.
            ok = _precode_kraft_mask(bits, cand)
            if stats is not None:
                stats.invalid_precode_histogram += int(np.count_nonzero(~ok))
            cand = cand[ok]

        for c in cand:
            abs_off = int(c) - rel + pos
            if not full_validation:
                if stats is not None:
                    stats.valid += 1
                yield abs_off
                continue
            # (5)-(7): full strict header parse.
            try:
                br = BitReader(data, abs_off)
                br.skip(3)
                read_dynamic_header(br, strict=True)
            except (DeflateError, EndOfStream) as exc:
                if stats is not None:
                    msg = str(exc)
                    if msg.startswith("distance code"):
                        stats.invalid_distance += 1
                    elif msg.startswith("literal code"):
                        stats.invalid_literal += 1
                    else:
                        stats.invalid_precode_data += 1
                continue
            if stats is not None:
                stats.valid += 1
            yield abs_off
        pos = batch_end


# ---------------------------------------------------------------------------
# Non-Compressed Block finder (paper §3.4.1)
# ---------------------------------------------------------------------------

def scan_stored_candidates(
    data,
    start_bit: int,
    end_bit: int,
    *,
    batch_bytes: int = 1 << 20,
) -> Iterator[int]:
    """Yield canonical NCB candidate offsets (``8*p - 3``) in [start_bit, end_bit).

    Checks: top 3 bits of the preceding byte zero (non-final, type 00, zero
    padding) and LEN == ~NLEN. False-positive rate ~1/512 KiB on random data
    (paper §3.4.1).
    """
    n_bytes = len(data)
    # p is the byte offset of LEN; candidate bit offset is 8p-3.
    p_min = max(1, (start_bit + 3 + 7) // 8)
    p_max_total = n_bytes - 4  # LEN+NLEN must fit
    pos = p_min
    while pos <= p_max_total:
        hi = min(pos + batch_bytes, p_max_total + 1)
        buf = np.frombuffer(data, dtype=np.uint8, count=min(hi + 4, n_bytes) - (pos - 1), offset=pos - 1)
        m = hi - pos  # number of candidate byte positions in this batch
        prev = buf[0:m]
        len_lo = buf[1 : 1 + m].astype(np.uint32)
        len_hi = buf[2 : 2 + m].astype(np.uint32)
        nlen_lo = buf[3 : 3 + m].astype(np.uint32)
        nlen_hi = buf[4 : 4 + m].astype(np.uint32)
        length = len_lo | (len_hi << 8)
        nlen = nlen_lo | (nlen_hi << 8)
        ok = ((prev & 0xE0) == 0) & (length == (~nlen & 0xFFFF))
        for i in np.nonzero(ok)[0]:
            p = pos + int(i)
            off = 8 * p - 3
            if start_bit <= off < end_bit:
                yield off
        pos = hi


# ---------------------------------------------------------------------------
# Combined finder (paper §3.4: lower offset of the two specialized finders)
# ---------------------------------------------------------------------------

class CombinedBlockFinder:
    """Merged Dynamic + Non-Compressed candidate stream for one chunk."""

    def __init__(self, data, start_bit: int, end_bit: int, *, stats: Optional[FilterStats] = None):
        self._dyn = scan_dynamic_candidates(data, start_bit, end_bit, stats=stats)
        self._ncb = scan_stored_candidates(data, start_bit, end_bit)
        self._dyn_next = next(self._dyn, None)
        self._ncb_next = next(self._ncb, None)

    def __iter__(self) -> "CombinedBlockFinder":
        return self

    def __next__(self) -> int:
        d, s = self._dyn_next, self._ncb_next
        if d is None and s is None:
            raise StopIteration
        if s is None or (d is not None and d <= s):
            self._dyn_next = next(self._dyn, None)
            if s is not None and d == s:  # dedupe identical offsets
                self._ncb_next = next(self._ncb, None)
            return d
        self._ncb_next = next(self._ncb, None)
        return s


# ---------------------------------------------------------------------------
# Sequential skip-LUT finder (paper's own walk — kept for Table 2 parity)
# ---------------------------------------------------------------------------

_SKIP_LUT_BITS = 14


def _build_skip_lut() -> np.ndarray:
    """skip[v] = bits to advance to the first plausible candidate in window v.

    For shifts where the full (final, type, HLIT) prefix is visible the check
    is exact; for shifts with only partial visibility the skip is
    conservative (candidate assumed plausible).
    """
    size = 1 << _SKIP_LUT_BITS
    lut = np.empty(size, dtype=np.uint8)
    for v in range(size):
        skip = _SKIP_LUT_BITS  # nothing plausible in the whole window
        for s in range(_SKIP_LUT_BITS):
            vis = _SKIP_LUT_BITS - s
            w = v >> s
            if vis >= 1 and (w & 1) != 0:  # final bit must be 0
                continue
            if vis >= 2 and (w >> 1) & 1 != 0:  # type LSB must be 0
                continue
            if vis >= 3 and (w >> 2) & 1 != 1:  # type MSB must be 1
                continue
            if vis >= 8:
                hlit = (w >> 3) & 31
                if hlit >= 30:
                    continue
            skip = s
            break
        lut[v] = skip
    return lut


_SKIP_LUT: Optional[np.ndarray] = None


def skip_lut() -> np.ndarray:
    global _SKIP_LUT
    if _SKIP_LUT is None:
        _SKIP_LUT = _build_skip_lut()
    return _SKIP_LUT


def find_dynamic_skiplut(data, start_bit: int, end_bit: int) -> Iterator[int]:
    """Sequential Dynamic-Block walk using the 14-bit skip-LUT."""
    lut = skip_lut()
    total_bits = len(data) * 8
    end = min(end_bit, total_bits - _HEADER_PROBE_BITS)
    br = BitReader(data)
    pos = start_bit
    while pos < end:
        br.seek(pos)
        window = br.peek(_SKIP_LUT_BITS)
        s = int(lut[window])
        if s > 0:
            pos += s
            continue
        # Plausible prefix at pos: run the precode + full checks.
        try:
            br2 = BitReader(data, pos)
            br2.skip(3)
            read_dynamic_header(br2, strict=True)
            yield pos
        except (DeflateError, EndOfStream):
            pass
        pos += 1


def find_dynamic_trial(data, start_bit: int, end_bit: int) -> Iterator[int]:
    """Naive trial parse at every offset ("DBF custom deflate", Table 2)."""
    total_bits = len(data) * 8
    end = min(end_bit, total_bits - _HEADER_PROBE_BITS)
    for pos in range(start_bit, end):
        try:
            br = BitReader(data, pos)
            final = br.read(1)
            btype = br.read(2)
            if final or btype != 2:
                continue
            read_dynamic_header(br, strict=True)
            yield pos
        except (DeflateError, EndOfStream):
            continue


def find_dynamic_zlib(data, start_bit: int, end_bit: int) -> Iterator[int]:
    """Trial decompression with zlib at byte-shifted offsets ("DBF zlib").

    zlib cannot start at a bit offset, so each trial bit-shifts the buffer —
    this is exactly why it is the slowest finder in paper Table 2.
    """
    import zlib

    from .zlib_bridge import shift_bitstream

    total_bits = len(data) * 8
    end = min(end_bit, total_bits - _HEADER_PROBE_BITS)
    for pos in range(start_bit, end):
        shifted = shift_bitstream(data, pos, max_bytes=1 << 12)
        d = zlib.decompressobj(wbits=-15)
        try:
            d.decompress(shifted)
        except zlib.error:
            continue
        # Require some progress and a dynamic block prefix.
        first3 = shifted[0] & 7
        if first3 == 0b100:  # final=0, type=01 LSB-first
            yield pos
