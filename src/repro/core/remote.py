"""RemoteFileReader — stateless HTTP(S) range-GET preads (paper §3, Fig 5).

The paper hides all file access behind the ``FileReader`` pread abstraction
precisely so the cache + prefetcher + thread pool can serve *any* byte
source. This module is that promise cashed in for remote objects: every
``pread`` maps to an HTTP ``Range: bytes=a-b`` request, so the service layer
can decompress and seek inside archives it never fully downloads.

Why the architecture transfers (paper §3.2): the adaptive prefetcher (Gill &
Bathen's AMP lineage) exists to hide *decompression* latency behind parallel
speculative work — the same mechanism hides *network round-trip* latency
here, because prefetched chunks issue their range-GETs concurrently from the
worker pool while the consumer drains earlier chunks. And the indexed read
path (random access into compressed data, paper §1.3/Fig 9) turns a warm
seek-index into O(range) remote traffic: a read of N decompressed bytes
touches only the compressed spans of the chunks that contain it.

Mechanics:

  * **Block-aligned readahead cache** — preads are rounded out to
    ``block_size`` boundaries and whole blocks are cached (LRU, bounded by
    ``cache_blocks``), so the many small header/footer probes the reader
    issues (gzip header parse, BGZF sniff, footers) ride one round trip.
    Adjacent missing blocks coalesce into a single range request;
    ``readahead_blocks`` extends each fetch run speculatively.
  * **Bounded retry** — 5xx/408/429, timeouts, connection resets, and short
    bodies retry with exponential backoff up to ``max_retries``; exhaustion
    raises ``RemoteIOError``. A ``Retry-After`` header on a throttled
    response (429/503 from an admission-controlled gateway) overrides the
    computed backoff, clamped to ``backoff_max``.
  * **Connection reuse** — one persistent HTTP/1.1 connection per thread
    (the chunk fetcher preads from many worker threads concurrently).
  * **Validators** — ETag/Last-Modified are captured at open and sent back
    via ``If-Range``; any response whose validators (or total size) disagree
    raises ``RemoteFileChangedError`` instead of serving corrupt bytes.
    When the server supplies a validator, mixing bytes from two object
    versions can never happen: a pread either completes against the
    open-time version or raises. Validator-less servers cannot be
    change-detected mid-read (only a size change is caught); for those,
    ``identity()`` returns None so the IndexStore keys indexes by content
    digest rather than trusting the URL.
"""

from __future__ import annotations

import http.client
import threading
import time
import urllib.parse
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from ..obs import trace as _obs_trace
from .cache import CacheStats, LRUCache
from .errors import RemoteFileChangedError, RemoteIOError
from .filereader import FileReader, check_pread_args

#: Response codes worth retrying: server-side faults and throttling.
TRANSIENT_STATUS = frozenset({408, 429, 500, 502, 503, 504})


def is_remote_url(source) -> bool:
    """True for http(s):// URL strings (the sources this backend serves)."""
    return isinstance(source, str) and source.startswith(("http://", "https://"))


def remote_identity(url: str, **kwargs) -> Optional[str]:
    """Identity string for a remote object (URL + ETag/Last-Modified + size).

    One HEAD round trip, no body bytes — cheap enough for IndexStore key
    derivation on every open. None when the server supplies no validator
    (callers must fall back to a content digest: URL + size alone would
    collide a same-size object replacement with its predecessor).
    """
    kwargs.setdefault("cache_blocks", 1)
    with RemoteFileReader(url, **kwargs) as reader:
        return reader.identity()


@dataclass
class RemoteStats:
    """Network-side counters; block-cache counters live in ``cache_stats``
    (the shared ``CacheStats`` shape the service metrics understand)."""

    requests: int = 0  # HTTP requests issued (incl. the open-time probe)
    retries: int = 0  # re-attempts after a transient failure
    retry_after_waits: int = 0  # retries paced by a server Retry-After header
    bytes_fetched: int = 0  # body bytes received from range responses

    def as_dict(self) -> Dict[str, int]:
        return {k: int(getattr(self, k)) for k in self.__dataclass_fields__}


class RemoteFileReader(FileReader):
    """Positioned reads over HTTP(S) via single-range GETs (stdlib only)."""

    def __init__(
        self,
        url: str,
        *,
        block_size: int = 1 << 20,
        cache_blocks: int = 16,
        readahead_blocks: int = 0,
        max_retries: int = 5,
        backoff_base: float = 0.05,
        backoff_max: float = 2.0,
        timeout: float = 30.0,
        headers: Optional[Dict[str, str]] = None,
        sleep: Callable[[float], None] = time.sleep,
        block_cache: Optional[LRUCache] = None,
    ):
        if not is_remote_url(url):
            raise ValueError("not an http(s) URL: %r" % (url,))
        if block_size < 1:
            raise ValueError("block_size must be positive")
        split = urllib.parse.urlsplit(url)
        if not split.netloc:
            raise ValueError("URL has no host: %r" % (url,))
        self._url = url
        self._scheme = split.scheme
        self._netloc = split.netloc
        self._path = split.path or "/"
        if split.query:
            self._path += "?" + split.query
        self._headers = dict(headers or {})
        self._block_size = block_size
        self._cache_blocks = max(1, cache_blocks)
        self._readahead_blocks = max(0, readahead_blocks)
        self._max_retries = max(0, max_retries)
        self._backoff_base = backoff_base
        self._backoff_max = backoff_max
        self._timeout = timeout
        self._sleep = sleep

        self._local = threading.local()
        self._conn_lock = threading.Lock()
        self._conns: List[http.client.HTTPConnection] = []
        self._closed = False

        # Block cache: the same thread-safe LRU the chunk fetcher uses
        # (capacity in entries = blocks); hit/miss/eviction accounting comes
        # with it. The in-flight map makes block fetches single-flight:
        # worker threads racing on the same cold block wait for one range
        # GET instead of each issuing their own. An *injected* cache (the
        # service layer passes a pool-backed one) charges these blocks —
        # up to cache_blocks x block_size resident bytes — to the owning
        # tenant's CachePool budget instead of sitting beside it; close()
        # then releases it back to the pool.
        if block_cache is not None:
            self._cache = block_cache
            self._cache_blocks = max(1, getattr(block_cache, "capacity", cache_blocks))
        else:
            self._cache = LRUCache(self._cache_blocks)
        self._inflight: Dict[int, threading.Event] = {}
        self._inflight_lock = threading.Lock()
        self.stats = RemoteStats()
        self._stats_lock = threading.Lock()

        self._etag: Optional[str] = None
        self._last_modified: Optional[str] = None
        try:
            self._size = self._probe()
        except BaseException:
            # A failed construction is never returned, so nothing could
            # ever close() us — release the probe's registered connection
            # here or each caller retry leaks a socket.
            self.close()
            raise

    # -- metadata -----------------------------------------------------------

    @property
    def url(self) -> str:
        return self._url

    @property
    def etag(self) -> Optional[str]:
        return self._etag

    @property
    def last_modified(self) -> Optional[str]:
        return self._last_modified

    def size(self) -> int:
        return self._size

    @property
    def cache_stats(self) -> CacheStats:
        """Block-cache hit/miss/eviction counters."""
        return self._cache.stats

    def identity(self) -> Optional[str]:
        validator = self._etag or self._last_modified
        if validator is None:
            # No validator: (url, size) cannot distinguish a same-size
            # object replacement, and _check_validators would have nothing
            # to catch it with at read time either. Returning None sends
            # file_identity to its head/tail content-digest fallback.
            return None
        return "remote\0%s\0%s\0%d" % (self._url, validator, self._size)

    # -- connection management ---------------------------------------------

    def _connection(self) -> http.client.HTTPConnection:
        conn = getattr(self._local, "conn", None)
        if conn is None:
            cls = (
                http.client.HTTPSConnection
                if self._scheme == "https"
                else http.client.HTTPConnection
            )
            conn = cls(self._netloc, timeout=self._timeout)
            self._local.conn = conn
            with self._conn_lock:
                self._conns.append(conn)
        return conn

    def _drop_connection(self) -> None:
        conn = getattr(self._local, "conn", None)
        if conn is not None:
            self._local.conn = None
            with self._conn_lock:
                try:
                    self._conns.remove(conn)
                except ValueError:
                    pass
            try:
                conn.close()
            except OSError:
                pass

    def close(self) -> None:
        self._closed = True
        with self._conn_lock:
            conns, self._conns = self._conns, []
        for conn in conns:
            try:
                conn.close()
            except OSError:
                pass
        # A pool-backed injected cache must be *released* (deregistered, its
        # bytes returned to the tenant budget), not just emptied — same
        # duck-typed contract the chunk fetcher uses for its caches.
        release = getattr(self._cache, "release", None)
        if release is not None:
            release()
        else:
            self._cache.clear()

    # -- HTTP plumbing ------------------------------------------------------

    def _do_request(self, method: str, extra_headers: Dict[str, str]):
        """One request/response on this thread's connection.

        Returns (status, message, body). Raises OSError/HTTPException on
        transport faults (the caller's retry loop owns recovery).
        """
        conn = self._connection()
        headers = {**self._headers, **extra_headers}
        # Wire-level trace propagation: when a span is current (this request
        # was issued under tracing), the traceparent header lets the serving
        # gateway stitch its own spans into our trace. One contextvar read
        # per request; absent while tracing is off.
        tp = _obs_trace.current_traceparent()
        if tp is not None:
            headers.setdefault(_obs_trace.TRACEPARENT_HEADER, tp)
        conn.request(method, self._path, headers=headers)
        resp = conn.getresponse()
        # Always drain the response (HEAD drains to b"" — http.client knows
        # the method has no body) or the connection cannot be reused.
        body = resp.read()
        with self._stats_lock:
            self.stats.requests += 1
        if resp.will_close:
            self._drop_connection()
        return resp.status, resp.headers, body

    def _check_validators(self, headers) -> None:
        etag = headers.get("ETag")
        if etag is not None and self._etag is not None:
            if etag != self._etag:
                raise RemoteFileChangedError(
                    "%s: ETag changed from %s to %s" % (self._url, self._etag, etag)
                )
            return
        # ETag unusable on one side or the other (intermediaries strip it,
        # and it can be absent at open yet present later): fall through to
        # Last-Modified so a replaced object is still caught.
        lm = headers.get("Last-Modified")
        if self._last_modified is not None and lm is not None and lm != self._last_modified:
            raise RemoteFileChangedError(
                "%s: Last-Modified changed from %s to %s"
                % (self._url, self._last_modified, lm)
            )

    def _retry_wait(self, attempt: int, retry_after: Optional[float] = None) -> None:
        with self._stats_lock:
            self.stats.retries += 1
            if retry_after is not None:
                self.stats.retry_after_waits += 1
        delay = min(self._backoff_max, self._backoff_base * (2 ** attempt))
        if retry_after is not None:
            # Server-directed pacing (429/503 Retry-After) wins over our own
            # backoff, but stays bounded by backoff_max so a hostile header
            # cannot park the thread.
            delay = min(max(delay, retry_after), self._backoff_max)
        if delay > 0:
            self._sleep(delay)

    def _probe(self) -> int:
        """Open-time HEAD (falling back to a 1-byte range GET): capture size
        and validators against which every later response is checked."""
        last_exc: Optional[BaseException] = None
        retry_after: Optional[float] = None
        for attempt in range(self._max_retries + 1):
            if attempt:
                self._retry_wait(attempt - 1, retry_after)
            retry_after = None
            try:
                status, headers, _ = self._do_request("HEAD", {})
                if status in (405, 501):
                    # No HEAD support: a 1-byte range response carries the
                    # total size in Content-Range and the same validators.
                    status, headers, _ = self._do_request(
                        "GET", {"Range": "bytes=0-0"}
                    )
            except (OSError, http.client.HTTPException) as exc:
                self._drop_connection()
                last_exc = exc
                continue
            if status in TRANSIENT_STATUS:
                retry_after = parse_retry_after(headers.get("Retry-After"))
                last_exc = RemoteIOError("HTTP %d probing %s" % (status, self._url))
                continue
            size: Optional[int] = None
            if status == 200:
                cl = headers.get("Content-Length")
                size = int(cl) if cl is not None else None
            elif status == 206:
                size = _parse_content_range(headers.get("Content-Range"))[1]
            else:
                raise RemoteIOError("HTTP %d probing %s" % (status, self._url))
            if size is None:
                raise RemoteIOError(
                    "%s: server reported no usable size (Content-Length/"
                    "Content-Range missing)" % self._url
                )
            self._etag = headers.get("ETag")
            self._last_modified = headers.get("Last-Modified")
            return size
        raise RemoteIOError(
            "probe of %s failed after %d attempts: %s"
            % (self._url, self._max_retries + 1, last_exc)
        ) from last_exc

    def _fetch_range(self, start: int, end_incl: int) -> bytes:
        """Fetch [start, end_incl] with bounded retry + validator checks."""
        if not _obs_trace.tracing_enabled():
            return self._fetch_range_raw(start, end_incl)
        with _obs_trace.span(
            "remote.range_get",
            {"start": start, "size": end_incl - start + 1, "url": self._url},
        ):
            return self._fetch_range_raw(start, end_incl)

    def _fetch_range_raw(self, start: int, end_incl: int) -> bytes:
        want = end_incl - start + 1
        extra = {"Range": "bytes=%d-%d" % (start, end_incl)}
        if self._etag is not None:
            extra["If-Range"] = self._etag
        last_exc: Optional[BaseException] = None
        retry_after: Optional[float] = None
        for attempt in range(self._max_retries + 1):
            if attempt:
                self._retry_wait(attempt - 1, retry_after)
            retry_after = None
            try:
                status, headers, body = self._do_request("GET", extra)
            except (OSError, http.client.HTTPException) as exc:
                # Timeout, reset, or a short body the transport detected
                # (IncompleteRead): transient — new connection, try again.
                self._drop_connection()
                last_exc = exc
                continue
            if status in TRANSIENT_STATUS:
                retry_after = parse_retry_after(headers.get("Retry-After"))
                last_exc = RemoteIOError(
                    "HTTP %d for bytes=%d-%d of %s" % (status, start, end_incl, self._url)
                )
                continue
            if status == 206:
                self._check_validators(headers)
                cr_start, total = _parse_content_range(headers.get("Content-Range"))
                if total is not None and total != self._size:
                    raise RemoteFileChangedError(
                        "%s: size changed from %d to %d" % (self._url, self._size, total)
                    )
                if cr_start is not None and cr_start != start:
                    # A proxy served a differently-aligned partial object:
                    # body[0] is not our requested start byte, so slicing it
                    # would cache wrong bytes under right keys. Transient —
                    # a retry may reach a conformant origin.
                    last_exc = RemoteIOError(
                        "misaligned Content-Range (starts at %d, wanted %d) from %s"
                        % (cr_start, start, self._url)
                    )
                    continue
                if len(body) < want:
                    # Short body under a healthy status line: transient.
                    last_exc = RemoteIOError(
                        "short range body (%d < %d) from %s" % (len(body), want, self._url)
                    )
                    self._drop_connection()
                    continue
                with self._stats_lock:
                    self.stats.bytes_fetched += want
                return body[:want]
            if status == 200:
                # Server ignored the Range header — either it simply does
                # not do ranges, or our If-Range validator no longer
                # matched. Distinguish via validators/size, then slice.
                self._check_validators(headers)
                if len(body) != self._size:
                    raise RemoteFileChangedError(
                        "%s: full body size %d != open-time size %d"
                        % (self._url, len(body), self._size)
                    )
                with self._stats_lock:
                    self.stats.bytes_fetched += len(body)
                # We paid for the whole object — bank as much of it as the
                # cache holds, forward from the requested run, so sequential
                # reads against a range-less server don't re-download the
                # full body per run.
                bs = self._block_size
                first = start // bs
                for i in range(self._cache_blocks):
                    lo = (first + i) * bs
                    if lo >= len(body):
                        break
                    self._install_block(first + i, body[lo : lo + bs])
                return body[start : end_incl + 1]
            if status == 416:
                raise RemoteFileChangedError(
                    "%s: range bytes=%d-%d no longer satisfiable (object shrank?)"
                    % (self._url, start, end_incl)
                )
            raise RemoteIOError(
                "HTTP %d for bytes=%d-%d of %s" % (status, start, end_incl, self._url)
            )
        raise RemoteIOError(
            "range GET bytes=%d-%d of %s failed after %d attempts: %s"
            % (start, end_incl, self._url, self._max_retries + 1, last_exc)
        ) from last_exc

    # -- block cache + single-flight fetches --------------------------------

    def _install_block(self, b: int, data: bytes) -> None:
        self._cache.insert(b, data)

    def _fetch_run(self, first_block: int, last_block: int) -> bytes:
        """One range request covering a run of blocks."""
        bs = self._block_size
        start = first_block * bs
        end_incl = min(self._size, (last_block + 1) * bs) - 1
        return self._fetch_range(start, end_incl)

    def _claim(self, wanted: List[int]) -> Tuple[List[int], Dict[int, threading.Event]]:
        """Partition blocks into ours-to-fetch vs already-in-flight elsewhere."""
        mine: List[int] = []
        theirs: Dict[int, threading.Event] = {}
        with self._inflight_lock:
            for b in wanted:
                ev = self._inflight.get(b)
                if ev is None:
                    self._inflight[b] = threading.Event()
                    mine.append(b)
                else:
                    theirs[b] = ev
        return mine, theirs

    def _release(self, claimed: List[int]) -> None:
        with self._inflight_lock:
            for b in claimed:
                ev = self._inflight.pop(b, None)
                if ev is not None:
                    ev.set()

    def _fetch_missing(self, missing: List[int], last: int, blocks: Dict[int, bytes]) -> None:
        """Fill ``blocks`` for every index in ``missing`` (all <= ``last``).

        Single-flight: blocks another thread is already fetching are waited
        on, not re-downloaded — at parallelization N the chunk prefetcher's
        workers race on overlapping margins, and without deduplication cold
        reads fetch ~2x the archive over the wire.
        """
        bs = self._block_size
        wanted = set(missing)
        mine, theirs = self._claim(missing)
        try:
            runs: List[List[int]] = []
            for b in mine:
                if runs and b == runs[-1][1] + 1:
                    runs[-1][1] = b
                else:
                    runs.append([b, b])
            if runs and self._readahead_blocks and runs[-1][1] == last:
                # Speculatively extend the final fetch past the request: the
                # next sequential pread then lands in cache (latency hiding
                # one level below the chunk prefetcher). Extension blocks
                # must be free (uncached, unclaimed) to stay single-flight.
                max_block = (self._size - 1) // bs
                b = last + 1
                while b <= max_block and b - last <= self._readahead_blocks and b not in self._cache:
                    claimed, _ = self._claim([b])
                    if not claimed:
                        break
                    mine.extend(claimed)
                    runs[-1][1] = b
                    b += 1
            for lo, hi in runs:
                data = self._fetch_run(lo, hi)
                # Serve from the fetched buffer directly — a run longer
                # than the LRU capacity must not depend on its own blocks
                # surviving insertion; the cache is opportunistic readahead.
                for b in range(lo, hi + 1):
                    piece = data[(b - lo) * bs : (b - lo + 1) * bs]
                    self._install_block(b, piece)
                    if b in wanted:
                        blocks[b] = piece
        finally:
            self._release(mine)  # on failure too: waiters fall back below
        for b, ev in theirs.items():
            ev.wait()
            blocks[b] = self._get_or_fetch_single(b)

    def _get_or_fetch_single(self, b: int) -> bytes:
        """Cache lookup with single-flight refetch for a woken waiter whose
        block is gone (the other fetch failed, or a fetch run longer than
        the LRU evicted it before we woke). Claimed like any other fetch so
        multiple stranded waiters still share one range GET."""
        while True:
            # peek: pread's initial get() already recorded this logical
            # access as a miss (we did wait on the network); a stats-counted
            # hit here would double-book it, and the block is MRU already.
            val = self._cache.peek(b)
            if val is not None:
                return val
            mine, theirs = self._claim([b])
            if mine:
                try:
                    val = self._fetch_run(b, b)
                    self._install_block(b, val)
                    return val
                finally:
                    self._release(mine)
            theirs[b].wait()  # someone else claimed meanwhile: wait, recheck

    def pread(self, offset: int, size: int) -> bytes:
        check_pread_args(offset, size)
        if self._closed:
            raise ValueError("pread on closed RemoteFileReader")
        if offset >= self._size or size == 0:
            return b""
        size = min(size, self._size - offset)
        bs = self._block_size
        first = offset // bs
        last = (offset + size - 1) // bs

        blocks: Dict[int, bytes] = {}
        missing: List[int] = []
        for b in range(first, last + 1):
            data = self._cache.get(b)  # records one hit or miss per block
            if data is not None:
                blocks[b] = data
            else:
                missing.append(b)
        if missing:
            self._fetch_missing(missing, last, blocks)

        # Trim only the edge blocks, then one join — chunk-sized preads are
        # the decompression hot path, so avoid whole-result re-copies.
        head_skip = offset - first * bs
        if first == last:
            return blocks[first][head_skip : head_skip + size]
        parts = [blocks[b] for b in range(first, last + 1)]
        parts[0] = parts[0][head_skip:]
        tail_keep = offset + size - last * bs
        if tail_keep < len(parts[-1]):
            parts[-1] = parts[-1][:tail_keep]
        return b"".join(parts)


def parse_retry_after(value: Optional[str]) -> Optional[float]:
    """Seconds out of a Retry-After header (delta-seconds form only).

    The HTTP-date form is legal but never emitted by our gateway and rarely
    by object stores; it parses to None and the caller falls back to its own
    backoff. Negative/garbage values also parse to None.
    """
    if not value:
        return None
    try:
        seconds = float(value.strip())
    except ValueError:
        return None
    return seconds if seconds >= 0 else None


def _parse_content_range(value: Optional[str]) -> Tuple[Optional[int], Optional[int]]:
    """(start, total) out of 'bytes a-b/N'; None fields when absent/'*'."""
    if not value or "/" not in value:
        return None, None
    spec, total_s = value.rsplit("/", 1)
    total: Optional[int] = None
    total_s = total_s.strip()
    if total_s != "*":
        try:
            total = int(total_s)
        except ValueError:
            total = None
    start: Optional[int] = None
    spec = spec.strip()
    if spec.startswith("bytes") and "-" in spec:
        try:
            start = int(spec[len("bytes"):].strip().split("-", 1)[0])
        except ValueError:
            start = None
    return start, total
