"""Parallel CRC32: per-chunk CRCs merged with GF(2) combine.

The paper lists checksum verification as future work (§6); rapidgzip-JAX
implements it. Each chunk's CRC32 is computed independently on the thread
pool (``zlib.crc32`` or the Pallas slice-by-8 kernel) and the per-chunk
values are merged sequentially with the O(log n) zlib ``crc32_combine``
matrix trick — the merge touches 32-bit state only, so the sequential part
of checksumming is negligible (same Amdahl argument as window propagation).
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

_POLY = 0xEDB88320


def _gf2_matrix_times(mat: Sequence[int], vec: int) -> int:
    out = 0
    i = 0
    while vec:
        if vec & 1:
            out ^= mat[i]
        vec >>= 1
        i += 1
    return out


def _gf2_matrix_square(mat: Sequence[int]) -> List[int]:
    return [_gf2_matrix_times(mat, mat[i]) for i in range(32)]


def crc32_combine(crc1: int, crc2: int, len2: int) -> int:
    """CRC32 of the concatenation of two blocks (zlib's crc32_combine)."""
    if len2 <= 0:
        return crc1 & 0xFFFFFFFF
    # Operator for one zero bit.
    odd = [_POLY] + [1 << (i - 1) for i in range(1, 32)]
    even = _gf2_matrix_square(odd)  # two zero bits
    odd = _gf2_matrix_square(even)  # four zero bits
    crc1 &= 0xFFFFFFFF
    crc2 &= 0xFFFFFFFF
    # Apply len2 zero bytes to crc1, alternating the squared operators.
    do_odd = False
    n = len2
    while n:
        if do_odd:
            odd = _gf2_matrix_square(even)
            if n & 1:
                crc1 = _gf2_matrix_times(odd, crc1)
        else:
            even = _gf2_matrix_square(odd)
            if n & 1:
                crc1 = _gf2_matrix_times(even, crc1)
        do_odd = not do_odd
        n >>= 1
    return (crc1 ^ crc2) & 0xFFFFFFFF


class RunningCRC:
    """Sequential CRC folding of per-chunk (crc, length) parts."""

    def __init__(self) -> None:
        self.crc = 0
        self.length = 0

    def add(self, crc: int, length: int) -> None:
        self.crc = crc32_combine(self.crc, crc, length)
        self.length += length

    def reset(self) -> None:
        self.crc = 0
        self.length = 0


def combine_parts(parts: Sequence[Tuple[int, int]]) -> int:
    """Fold [(crc, len), ...] left to right."""
    acc = RunningCRC()
    for crc, length in parts:
        acc.add(crc, length)
    return acc.crc
