"""ParallelGzipReader — seekable, parallel-decompressing file-like object
(paper §3.1, Fig 4/5).

Reading drives a *frontier* of sequential finalization over parallel
speculative chunk decompression:

  * ``read``/``seek`` only update the logical position (a seek does no work
    until the next read — paper §3.1).
  * Positions beyond the finalized frontier advance it: prefetched chunks are
    fetched from the cache (dispatching exact re-decodes on speculation
    misses), their windows propagated sequentially, marker replacement and
    CRC parts dispatched to the pool, and seek points appended to the
    on-the-fly index — including interior split points that bound the
    decompressed spacing (load balancing for the indexed pass, paper §1.4).
  * Positions behind the frontier are served through the seek-point index:
    O(1) to the chunk, zlib-delegated decompression, adaptive prefetch for
    sequential patterns.
  * BGZF files are detected and indexed directly from their metadata — the
    trivially-parallel fast path (paper §3.4.4).

The index can be exported/imported; with an imported index the first pass is
skipped entirely and every read is an indexed read (paper Fig 9 "with
index").

Concurrency contract: ``pread(offset, size)`` is a *stateless* positional
read — no shared cursor, safe from any number of threads at once. Ranges
already covered by the index are served with no reader-level lock at all
(index lookups and chunk fetches are thread-safe on their own); only
advancing the speculative first pass is serialized, behind a narrow
*frontier lock* taken one chunk at a time. ``read``/``seek``/``tell`` keep
the classic file-object cursor and are only safe from one thread, but they
ride the same machinery, so a cursor reader and many pread callers can share
one instance.
"""

from __future__ import annotations

import io
import threading
import time as _time
import zlib as _zlib
from typing import List, Optional, Union

import numpy as np

from ..obs import trace as _obs_trace
from .chunk_fetcher import FinalizedChunk, ChunkFetcher
from .codec import Codec, DeflateCodec, detect_codec, resolve_codec
from .crc32 import crc32_combine
from .deflate import BT_FIXED
from .errors import FormatError, GzipFooterError, RapidgzipError
from .filereader import open_file_reader
from .index import (
    FLAG_HAS_INTERIOR_MEMBER_END,
    FLAG_ZLIB_UNSAFE,
    GzipIndex,
    SeekPoint,
)
from .markers import full_window

#: A pread nested under a service span records its own ring entry only when
#: it ran at least this long: below it, the read was served from cache and
#: its interval is already covered by the parent span. Reads that did real
#: work clear the floor comfortably — decompressing even one cold chunk
#: takes multiple milliseconds, a remote range-GET tens of ms.
_NESTED_PREAD_RECORD_S = 5e-4


class ParallelGzipReader(io.RawIOBase):
    """File-like object exposing the decompressed stream of a gzip file."""

    def __init__(
        self,
        source,
        *,
        parallelization: int = 4,
        chunk_size: int = 4 << 20,
        index: Optional[Union[GzipIndex, str, bytes]] = None,
        verify: bool = True,
        framing: str = "gzip",
        codec: Union[None, str, Codec] = None,
        index_spacing: Optional[int] = None,
        access_cache_size: int = 1,
        executor=None,
        access_cache=None,
        prefetch_cache=None,
        prefetch_strategy=None,
        resolver=None,
    ):
        super().__init__()
        self._reader = open_file_reader(source)
        try:
            self._verify = verify
            self._framing = framing
            # Decompressed spacing between seek points; chunks whose
            # decompressed size exceeds it are split at interior block
            # boundaries (paper §1.4).
            self._index_spacing = index_spacing or 4 * chunk_size

            if isinstance(index, str):
                index = GzipIndex.import_file(index)
            elif isinstance(index, (bytes, bytearray)):
                index = GzipIndex.from_bytes(bytes(index))

            # Codec resolution, cheapest evidence first: an explicit
            # instance/tag wins; raw framing is deflate by definition; a
            # finalized imported index names its own codec (no head read —
            # remote sources skip a round trip); otherwise probe the head
            # bytes (BGZF before gzip before the deflate fallback — valid
            # gzip can never error here, satellite guarantee).
            if isinstance(codec, Codec) or isinstance(codec, str):
                self._codec = resolve_codec(codec, framing=framing)
            elif framing == "raw":
                self._codec = DeflateCodec(framing="raw")
            elif index is not None and index.finalized:
                self._codec = resolve_codec(index.codec_tag)
            else:
                self._codec = detect_codec(self._reader.pread(0, 1 << 12))

            self._fetcher = ChunkFetcher(
                self._reader,
                chunk_size=chunk_size,
                parallelization=parallelization,
                framing=framing,
                codec=self._codec,
                index=index,
                access_cache_size=access_cache_size,
                executor=executor,
                access_cache=access_cache,
                prefetch_cache=prefetch_cache,
                prefetch_strategy=prefetch_strategy,
                resolver=resolver,
            )
            self._index = self._fetcher.index

            self._pos = 0
            self._eos = False
            self._frontier_bit = 0
            self._frontier_out = 0
            self._window: Optional[bytes] = b""
            self._member_crc = 0
            self._member_len = 0
            # Serializes first-pass advancement; indexed reads never take it.
            self._frontier_lock = threading.Lock()
            self._frontier_acquires = 0
            self._frontier_contended = 0
            self._frontier_wait_s = 0.0

            if self._index.finalized:
                # Imported index: no first pass needed.
                self._eos = True
                self._frontier_out = self._index.decompressed_size or 0
            elif self._build_exact_index():
                # Metadata-only index (BGZF member walk, zstd seek table):
                # the trivially-parallel path — zero speculative decoding.
                self._eos = True
                self._frontier_out = self._index.decompressed_size or 0
            else:
                self._frontier_bit = self._codec.leading_header_bits(self._reader)
        except BaseException:
            # A half-built reader must not leak: header parsing or index
            # import raising here would otherwise strand the opened
            # FileReader (an FD, or remote connections) and — when the
            # fetcher was already constructed — leave pooled caches and the
            # executor view registered against shared service budgets.
            try:
                fetcher = getattr(self, "_fetcher", None)
                if fetcher is not None:
                    fetcher.shutdown()
                else:
                    # The fetcher would have owned releasing the injected
                    # caches; it never existed, so release them ourselves.
                    for cache in (access_cache, prefetch_cache):
                        release = getattr(cache, "release", None)
                        if release is not None:
                            release()
            finally:
                self._reader.close()
                # Mark the stream closed so the interpreter's later
                # RawIOBase.__del__ -> close() does not re-run teardown on
                # the half-built object (double cache release / double
                # shutdown).
                super().close()
            raise

    # ------------------------------------------------------------------
    # setup
    # ------------------------------------------------------------------

    def _build_exact_index(self) -> bool:
        """Try the codec's metadata-only index (paper §3.4.4's fast path).

        Built into a scratch index and installed atomically on success: a
        scan failing midway (e.g. a file whose first member is BGZF but
        whose later members are plain gzip) must leave the shared index
        untouched, because its partial points would poison the speculative
        pass's on-the-fly `add_point` ordering. On such a failure a codec
        that supports speculation falls back to it — valid gzip never
        errors out of auto-detection.
        """
        tmp = GzipIndex(codec_tag=self._codec.tag)
        try:
            if not self._codec.build_exact_index(self._reader, tmp):
                return False
        except FormatError:
            if self._codec.supports_speculation:
                return False
            raise
        for p in tmp.points():
            self._index.add_point(p)
        self._index.finalize(tmp.decompressed_size or 0, tmp.compressed_size or 0)
        return True

    # ------------------------------------------------------------------
    # frontier: first-pass parallel decompression + on-the-fly indexing
    # ------------------------------------------------------------------

    def _advance_frontier(self) -> None:
        """Advance the first pass by one chunk. Callers other than the
        constructor must hold ``_frontier_lock`` — this mutates the window,
        CRC running state, and the frontier offsets."""
        if self._eos:
            return
        res = self._fetcher.get_chunk_at(self._frontier_bit, window=self._window)
        fc = self._fetcher.finalize_async(res, self._window, self._frontier_out)
        self._collect(fc)
        self._window = fc.window_out
        self._frontier_bit = res.end_bit
        self._frontier_out += res.size
        if res.ended_at_eos:
            # Finalize the index *before* publishing EOS: lock-free pread
            # callers treat `_eos` as "the index now answers everything" —
            # seeing it early would turn an in-range read into a short one.
            self._index.finalize(self._frontier_out, self._reader.size())
            self._eos = True

    def _advance_frontier_past(self, pos: int) -> None:
        """Take the frontier lock and advance the first pass one chunk,
        unless a concurrent caller already made ``pos`` serveable. One chunk
        per acquisition keeps the critical section narrow: concurrent
        readers waiting on different offsets interleave instead of one
        caller holding the lock across a long catch-up."""
        # Span covers lock wait + the one-chunk advance: in a trace of a
        # cold read this is the "frontier wait" row (first-pass work other
        # readers may be doing on our behalf shows up as sibling spans).
        with _obs_trace.span("reader.frontier_wait", {"pos": pos}) as sp:
            if self._frontier_lock.acquire(blocking=False):
                self._frontier_acquires += 1
            else:
                t0 = _time.perf_counter()
                self._frontier_lock.acquire()
                # Counters are only mutated while holding the frontier lock,
                # so plain int/float updates are race-free; readers may see a
                # slightly stale snapshot, which telemetry tolerates.
                self._frontier_acquires += 1
                self._frontier_contended += 1
                waited = _time.perf_counter() - t0
                self._frontier_wait_s += waited
                sp.set_attr("contended", True)
                sp.set_attr("lock_wait_s", round(waited, 6))
            try:
                if not self._eos and self._serveable_point(pos) is None:
                    self._advance_frontier()
            finally:
                self._frontier_lock.release()

    def _collect(self, fc: FinalizedChunk) -> None:
        """Sequential bookkeeping for one finalized chunk: CRC verification,
        seek points (with interior splits), and byte handoff to the cache."""
        data = fc.bytes()
        res = fc.result

        # -- CRC32 / ISIZE verification at member ends ---------------------
        if self._verify and self._codec.verifies_members:
            prev = 0
            for me in res.member_ends:
                seg = data[prev : me.out_offset]
                crc = self._fetcher.crc32(seg)
                self._member_crc = crc32_combine(self._member_crc, crc, int(seg.shape[0]))
                self._member_len += int(seg.shape[0])
                if self._member_crc != me.crc32:
                    raise GzipFooterError(
                        "CRC32 mismatch at decompressed offset %d"
                        % (fc.out_start + me.out_offset)
                    )
                if (self._member_len & 0xFFFFFFFF) != me.isize:
                    raise GzipFooterError("ISIZE mismatch")
                self._member_crc = 0
                self._member_len = 0
                prev = me.out_offset
            tail = data[prev:]
            if tail.shape[0]:
                crc = self._fetcher.crc32(tail)
                self._member_crc = crc32_combine(self._member_crc, crc, int(tail.shape[0]))
                self._member_len += int(tail.shape[0])

        # -- seek points ----------------------------------------------------
        cuts = self._split_offsets(fc)
        self._observe_chunk(res, cuts)
        first_bound = cuts[0][1] if cuts else fc.size
        point_flags = 0
        if any(0 < me.out_offset <= first_bound for me in res.member_ends):
            point_flags |= FLAG_HAS_INTERIOR_MEMBER_END
        starts = [(fc.start_bit, 0, point_flags)] + cuts
        bounds_for_flags = [s[1] for s in starts] + [fc.size]
        stored_offsets = self._codec.stored_block_offsets(res)
        ordinals: List[int] = []
        for j, (bit, local_out, flags) in enumerate(starts):
            # zlib delegation is unsafe when stored-block padding would not
            # survive the bit-shift realignment (see FLAG_ZLIB_UNSAFE).
            if bit % 8 != 0:
                lo, hi = local_out, bounds_for_flags[j + 1]
                if any(lo <= so < hi for so in stored_offsets):
                    flags |= FLAG_ZLIB_UNSAFE
            window = self._window_at(fc, local_out)
            self._index.add_point(SeekPoint(bit, fc.out_start + local_out, window, flags))
            ordinals.append(len(self._index) - 1)
        # Hand decompressed slices to the cache under their index keys so
        # trailing reads are free.
        bounds = [s[1] for s in starts] + [fc.size]
        for j, i_point in enumerate(ordinals):
            self._fetcher.put_indexed(i_point, data[bounds[j] : bounds[j + 1]])

    def _observe_chunk(self, res, cuts) -> None:
        """Record first-pass hostility observations on the in-memory index
        (``Codec.seek_hostility`` scores them once the index finalizes).
        Runs under the frontier lock, so plain dict updates are race-free."""
        obs = self._index.observations
        obs["chunks"] = obs.get("chunks", 0) + 1
        if res.marker_mode:
            obs["marker_chunks"] = obs.get("marker_chunks", 0) + 1
        if res.blocks and all(b.block_type == BT_FIXED for b in res.blocks):
            obs["fixed_chunks"] = obs.get("fixed_chunks", 0) + 1
        obs["split_points"] = obs.get("split_points", 0) + len(cuts)

    def _split_offsets(self, fc: FinalizedChunk):
        """Interior seek points bounding decompressed spacing (paper §1.4)."""
        res = fc.result
        cuts = []
        if fc.size <= self._index_spacing:
            return cuts
        next_cut = self._index_spacing
        for b in res.blocks[1:]:
            if b.out_offset < next_cut or b.is_final:
                continue
            cand = self._codec.split_candidate(b)
            if cand is None:
                continue  # the finder cannot resume at this block type
            bit, flags = cand
            # Member-boundary flag for the sub-chunk starting here.
            lo = b.out_offset
            hi = fc.size
            if any(lo < me.out_offset <= hi for me in res.member_ends):
                flags |= FLAG_HAS_INTERIOR_MEMBER_END
            cuts.append((bit, b.out_offset, flags))
            next_cut = b.out_offset + self._index_spacing
        # Fix member-end flags of earlier pieces: a piece has the flag iff a
        # member end falls strictly inside (start, next_start].
        fixed = []
        all_bounds = [c[1] for c in cuts] + [fc.size]
        for j, (bit, off, flags) in enumerate(cuts):
            lo, hi = off, all_bounds[j + 1]
            has = any(lo < me.out_offset <= hi for me in res.member_ends)
            flags = (flags | FLAG_HAS_INTERIOR_MEMBER_END) if has else (flags & ~FLAG_HAS_INTERIOR_MEMBER_END)
            fixed.append((bit, off, flags))
        return fixed

    def _window_at(self, fc: FinalizedChunk, local_out: int) -> bytes:
        wsize = self._codec.window_size
        if local_out == 0 or wsize == 0:
            return self._window if self._window is not None else b""
        data = fc.bytes()
        if local_out >= wsize:
            return data[local_out - wsize : local_out].tobytes()
        prev = full_window(self._window)
        combined = np.concatenate([prev, data[:local_out]])
        return combined[-wsize:].tobytes()

    # ------------------------------------------------------------------
    # io.RawIOBase interface
    # ------------------------------------------------------------------

    def readable(self) -> bool:
        return True

    def seekable(self) -> bool:
        return True

    def tell(self) -> int:
        return self._pos

    def seek(self, offset: int, whence: int = io.SEEK_SET) -> int:
        if whence == io.SEEK_SET:
            pos = offset
        elif whence == io.SEEK_CUR:
            pos = self._pos + offset
        elif whence == io.SEEK_END:
            pos = self.size() + offset
        else:
            raise ValueError("bad whence")
        if pos < 0:
            raise ValueError("negative seek position")
        self._pos = pos  # lazy: work happens on the next read (paper §3.1)
        return pos

    def size(self) -> int:
        """Decompressed size (drives the first pass to completion)."""
        while not self._eos:
            # frontier_out is never serveable pre-EOS, so each call advances
            # exactly one chunk (and concurrent callers share the work).
            self._advance_frontier_past(self._frontier_out)
        assert self._index.decompressed_size is not None
        return self._index.decompressed_size

    def _serveable_point(self, pos: int) -> Optional[int]:
        """Ordinal of the seek point that can serve ``pos`` through an
        indexed fetch *right now*, or None while the first pass must advance
        (or, at EOS, when ``pos`` is at/past the end of the stream)."""
        if pos >= self._frontier_out:
            return None
        i = self._index.find(pos)
        if i is None:
            raise RapidgzipError("position %d precedes the index" % pos)
        # The chunk's size must be bounded by a successor point (or the
        # finalized total) before an indexed fetch can run.
        if i + 1 >= len(self._index) and not self._index.finalized:
            return None
        return i

    def _read_span(self, pos: int, end: Optional[int]) -> bytes:
        """Decompressed bytes [pos, end) (to EOF when end is None) — the
        shared engine under ``read`` and ``pread``. Stateless: no cursor, no
        lock on the indexed path; the frontier lock only while the first
        pass must advance past uncovered positions."""
        out: List[bytes] = []
        while end is None or pos < end:
            # Snapshot EOS *before* probing: if EOS lands between the probe
            # and the check, the stale False routes us through the (no-op)
            # locked advance and we re-probe under the final index state
            # instead of breaking early with a short read.
            at_eos = self._eos
            i = self._serveable_point(pos)
            if i is None:
                if at_eos:
                    break  # at/past EOF
                self._advance_frontier_past(pos)
                continue
            data = self._fetcher.get_indexed(i)
            start = self._index.point_at(i).decompressed_byte
            off = pos - start
            avail = int(data.shape[0]) - off
            if avail <= 0:
                break  # pos beyond EOF (e.g. a stale index overstating coverage)
            take = avail if end is None else min(avail, end - pos)
            out.append(data[off : off + take].tobytes())
            pos += take
        return b"".join(out)

    def pread(self, offset: int, size: int) -> bytes:
        """Stateless positional read: decompressed [offset, offset+size),
        short at EOF. Thread-safe with no shared cursor — any number of
        threads may pread concurrently; index-covered ranges (always, once
        the index is finalized) are served entirely lock-free."""
        if offset < 0 or size < 0:
            raise ValueError("pread offset and size must be non-negative")
        if not _obs_trace.tracing_enabled():
            # One flag check is the entire disabled-tracing cost on the warm
            # lock-free path (the obs benchmark's "unmeasurable" claim).
            return self._read_span(offset, offset + size)
        if _obs_trace.current_context() is None:
            # Root read (direct reader use, no service boundary above): a
            # live span, so frontier/fetch children nest under it.
            with _obs_trace.span("reader.pread", {"offset": offset, "size": size}):
                return self._read_span(offset, offset + size)
        # Nested under a service boundary that already carries this read's
        # offset/size and ~duration (server.read_range, fleet.pread): the
        # parent's span and histogram cover the interval, so a fast read
        # here records nothing of its own — a live Span (or even one
        # histogram observe) per warm cache hit was most of the
        # enabled-tracing overhead the obs benchmark bounds at 5%. Only a
        # read slow enough to say something the parent does not (it did
        # first-pass or fetch work) lands in the ring and the histogram.
        t0 = _time.perf_counter()
        try:
            return self._read_span(offset, offset + size)
        finally:
            dur = _time.perf_counter() - t0
            if dur >= _NESTED_PREAD_RECORD_S:
                # record_span feeds the histogram itself.
                _obs_trace.record_span(
                    "reader.pread", t0, dur, {"offset": offset, "size": size}
                )

    def read(self, size: int = -1) -> bytes:
        data = self._read_span(self._pos, None if size < 0 else self._pos + size)
        self._pos += len(data)
        return data

    def readinto(self, b) -> int:
        data = self.read(len(b))
        b[: len(data)] = data
        return len(data)

    def cancel_prefetches(self) -> int:
        """Cancel this reader's *queued* batch-lane prefetch tasks.

        Used when the consumer that motivated the speculation is gone (a
        gateway client disconnecting mid-stream): queued prefetches are pure
        latency-hiding — dropping them frees executor bandwidth without
        affecting correctness, and the fetcher's dedup map resubmits on the
        next demand fetch. Priority-lane tasks (a live read is blocking on
        them) are never touched. Returns the number cancelled; 0 for plain
        executors without a scoped cancel.
        """
        cancel_pending = getattr(self._fetcher.pool, "cancel_pending", None)
        if cancel_pending is None:
            return 0
        try:
            return cancel_pending(batch_only=True)
        except TypeError:  # a duck-typed view without the kwarg
            return 0

    def close(self) -> None:
        if not self.closed:
            try:
                self._fetcher.shutdown()
            finally:
                # The file handle (and any remote connections) must close
                # even when a cache release / task cancel raises mid-shutdown.
                self._reader.close()
        super().close()

    # ------------------------------------------------------------------
    # index import/export & introspection
    # ------------------------------------------------------------------

    @property
    def index(self) -> GzipIndex:
        return self._index

    @property
    def codec(self) -> Codec:
        return self._codec

    def build_full_index(self) -> GzipIndex:
        self.size()  # drives the first pass to completion (frontier-locked)
        return self._index

    def seek_hostility(self) -> float:
        """The codec's seek-hostility score for this reader's index (0 when
        the first pass has not finished — only a fully built index can be
        judged)."""
        if not self._index.finalized:
            return 0.0
        return self._codec.seek_hostility(self._index)

    def export_index(self, dest) -> None:
        self.build_full_index()
        self._index.export_file(dest)

    def stats(self) -> dict:
        report = self._fetcher.cache_report()
        report["frontier"] = {
            "lock_acquires": int(self._frontier_acquires),
            "lock_contended": int(self._frontier_contended),
            "lock_wait_s": float(self._frontier_wait_s),
        }
        return report
