"""rapidgzip-JAX core: the paper's contribution.

Parallel decompression of and random access into arbitrary gzip files via
speculative two-stage chunk decoding wrapped in a cache + parallelized
prefetcher (Knespel & Brunst, HPDC '23).
"""

from .bitreader import BitReader
from .block_finder import (
    CombinedBlockFinder,
    FilterStats,
    find_dynamic_skiplut,
    find_dynamic_trial,
    scan_dynamic_candidates,
    scan_stored_candidates,
)
from .chunk_fetcher import ChunkFetcher, FinalizedChunk, GzipChunkFetcher
from .codec import (
    CODECS,
    BgzfCodec,
    Codec,
    DeflateCodec,
    ZstdCodec,
    detect_codec,
    detect_codec_tag,
    have_zstd,
    resolve_codec,
)
from .crc32 import RunningCRC, crc32_combine
from .deflate import (
    DecodeResult,
    DeflateChunkDecoder,
    MARKER_BASE,
    WINDOW_SIZE,
    canonical_stored_offset,
    gzip_decompress_sequential,
    inflate_raw,
)
from .errors import (
    BlockNotFoundError,
    DeflateError,
    FormatError,
    GzipFooterError,
    GzipHeaderError,
    RapidgzipError,
    RemoteFileChangedError,
    RemoteIOError,
)
from .filereader import (
    BytesFileReader,
    FileReader,
    PythonFileReader,
    SharedFileReader,
    open_file_reader,
)
from .remote import RemoteFileReader, is_remote_url, remote_identity
from .gzip_format import detect_bgzf, parse_gzip_header, scan_bgzf_members
from .index import GzipIndex, SeekPoint
from .markers import propagate_window, replace_markers, replacement_table
from .prefetch import AdaptivePrefetchStrategy, BackwardPrefetchStrategy, NoPrefetch
from .reader import ParallelGzipReader

__all__ = [
    "AdaptivePrefetchStrategy",
    "BackwardPrefetchStrategy",
    "BgzfCodec",
    "BitReader",
    "BlockNotFoundError",
    "BytesFileReader",
    "CODECS",
    "ChunkFetcher",
    "Codec",
    "CombinedBlockFinder",
    "DecodeResult",
    "DeflateChunkDecoder",
    "DeflateCodec",
    "DeflateError",
    "FileReader",
    "FilterStats",
    "FinalizedChunk",
    "FormatError",
    "GzipChunkFetcher",
    "GzipFooterError",
    "GzipHeaderError",
    "GzipIndex",
    "MARKER_BASE",
    "NoPrefetch",
    "ParallelGzipReader",
    "PythonFileReader",
    "RapidgzipError",
    "RemoteFileChangedError",
    "RemoteFileReader",
    "RemoteIOError",
    "RunningCRC",
    "SeekPoint",
    "SharedFileReader",
    "WINDOW_SIZE",
    "ZstdCodec",
    "canonical_stored_offset",
    "crc32_combine",
    "detect_bgzf",
    "detect_codec",
    "detect_codec_tag",
    "have_zstd",
    "resolve_codec",
    "find_dynamic_skiplut",
    "find_dynamic_trial",
    "gzip_decompress_sequential",
    "inflate_raw",
    "is_remote_url",
    "open_file_reader",
    "remote_identity",
    "parse_gzip_header",
    "propagate_window",
    "replace_markers",
    "replacement_table",
    "scan_bgzf_members",
    "scan_dynamic_candidates",
    "scan_stored_candidates",
]
