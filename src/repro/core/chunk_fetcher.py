"""Chunk fetcher: thread pool + caches + prefetcher (paper §3.2/§3.3, Figs 4&5).

Orchestrates parallel chunk decompression:

  * **Nominal (speculative) tasks** — prefetches for chunk index ``k`` run the
    block finder from the nominal offset ``k * chunk_size`` and trial-decode
    candidates until one survives to the stop condition. Results are cached
    keyed by their *actual* start bit offset.
  * **Exact tasks** — the main thread requests chunks by the exact end offset
    of the predecessor. A prefetch that found a false positive simply never
    matches any request key and ages out of the prefetch cache; the main
    thread re-dispatches an exact-offset task (paper §3: "robust against
    false positives").
  * **Indexed tasks** — once seek points exist, chunks decompress from their
    recorded (bit offset, window) — delegated to zlib where possible (paper
    §1.3: >2x faster than two-stage), falling back to the custom decoder for
    chunks containing gzip member boundaries.
  * **Finalization** — window propagation is the only sequential step (last
    32 KiB per chunk); full marker replacement and CRC parts run on the pool
    (paper §2.2's Amdahl mitigation).

Work distribution is dynamic: whichever worker is free takes the next
dispatched chunk — the paper's straggler mitigation (§4.2, §6).

``get_indexed`` is safe to call from many threads concurrently: caches,
in-flight dedup, and the index carry their own locks, and stateful prefetch
strategies are serialized behind ``_strategy_lock``. This is what lets
`ParallelGzipReader.pread` serve index-covered ranges with no reader-level
lock at all.
"""

from __future__ import annotations

import threading
import zlib as _zlib
from concurrent.futures import CancelledError, Future, ThreadPoolExecutor
from dataclasses import dataclass, field
import time as _time
from typing import Callable, Dict, List, Optional, Tuple, Union

import numpy as np

from ..obs import trace as _obs_trace
from .cache import LRUCache
from .codec import Codec, resolve_codec
from .deflate import DecodeResult
from .errors import BlockNotFoundError, DeflateError, EndOfStream, RapidgzipError
from .filereader import FileReader
from .index import (
    FLAG_HAS_INTERIOR_MEMBER_END,
    FLAG_STREAM_START,
    FLAG_ZLIB_UNSAFE,
    GzipIndex,
    SeekPoint,
)
from .prefetch import AdaptivePrefetchStrategy, PrefetchStrategy

DEFAULT_CHUNK_SIZE = 4 << 20  # paper §1.4: 4 MiB default compressed chunk size
#: deflate's maximum compression ratio is ~1032 (paper §1.4); the cap guards
#: against runaway false positives without rejecting any legal chunk.
MAX_COMPRESSION_RATIO = 1100


@dataclass
class FetcherStats:
    nominal_tasks: int = 0
    exact_tasks: int = 0
    indexed_tasks: int = 0
    candidates_tried: int = 0
    false_positive_starts: int = 0  # candidates that failed trial decompression
    false_positive_chunks: int = 0  # full chunk results never matched by a request
    redispatches: int = 0  # exact task after prefetch mismatch
    chunks_with_markers: int = 0
    zlib_delegations: int = 0
    bytes_decompressed: int = 0

    def as_dict(self) -> dict:
        return {k: int(getattr(self, k)) for k in self.__dataclass_fields__}


@dataclass
class FinalizedChunk:
    """A chunk whose window has been propagated; bytes may still be in flight."""

    start_bit: int
    end_bit: int
    out_start: int  # global decompressed offset of the chunk start
    size: int
    window_in: Optional[bytes]
    window_out: bytes
    result: DecodeResult
    _bytes_future: Optional[Future] = None
    _bytes: Optional[np.ndarray] = None
    #: CRC32 callable installed by the owning fetcher (resolver-aware);
    #: defaults to zlib for bare FinalizedChunks constructed in tests.
    _crc32: Optional[Callable] = None

    def bytes(self) -> np.ndarray:
        if self._bytes is None:
            assert self._bytes_future is not None
            self._bytes = self._bytes_future.result()
        return self._bytes

    def crc_segments(self) -> List[Tuple[int, int]]:
        """[(segment_length, crc32), ...] split at interior member ends."""
        data = self.bytes()
        crc = self._crc32 or (lambda seg: _zlib.crc32(seg.tobytes()) & 0xFFFFFFFF)
        cuts = [me.out_offset for me in self.result.member_ends]
        segs: List[Tuple[int, int]] = []
        prev = 0
        for c in cuts + [self.size]:
            seg = data[prev:c]
            segs.append((int(seg.shape[0]), crc(seg)))
            prev = c
        return segs


class ChunkFetcher:
    """Parallel chunk decompression engine over a FileReader.

    Format specifics live in ``codec`` (core.codec): candidate finding,
    chunk decoding, native delegation, and the marker machinery are all
    codec methods; everything in this class — caches, in-flight dedup,
    scheduling hints, prefetch strategy, stats — is codec-agnostic.
    """

    def __init__(
        self,
        reader: FileReader,
        *,
        chunk_size: int = DEFAULT_CHUNK_SIZE,
        parallelization: int = 4,
        framing: str = "gzip",
        codec: Union[None, str, Codec] = None,
        index: Optional[GzipIndex] = None,
        prefetch_strategy: Optional[PrefetchStrategy] = None,
        access_cache_size: int = 1,
        max_ratio: int = MAX_COMPRESSION_RATIO,
        executor=None,
        access_cache: Optional[LRUCache] = None,
        prefetch_cache: Optional[LRUCache] = None,
        resolver=None,
    ):
        if chunk_size < 1 << 10:
            raise ValueError("chunk_size must be >= 1 KiB")
        self.reader = reader
        self.chunk_size = chunk_size
        self.parallelization = max(1, parallelization)
        # codec=None keeps the historical default (deflate with the given
        # framing) — auto-detection happens one level up, in the reader,
        # which has the head bytes at hand.
        self.codec = resolve_codec(codec, framing=framing)
        self.framing = getattr(self.codec, "framing", framing)
        self.index = index if index is not None else GzipIndex(codec_tag=self.codec.tag)
        if self.index.codec_tag not in self.codec.index_compatible_tags:
            raise RapidgzipError(
                "index codec %r is not servable by the %r codec"
                % (self.index.codec_tag, self.codec.tag)
            )
        self.max_ratio = max_ratio
        self.file_size = reader.size()
        self.total_bits = self.file_size * 8
        self.n_nominal = max(1, -(-self.file_size // chunk_size))

        # The executor and both caches are injectable so a fleet of fetchers
        # can share one thread-pool budget and one memory budget
        # (service/server.py). An injected executor is externally owned:
        # shutdown() leaves it alone.
        self._owns_executor = executor is None
        self.pool = executor if executor is not None else ThreadPoolExecutor(
            max_workers=self.parallelization
        )
        # Separate caches: prefetch traffic must not evict accessed chunks
        # (paper §3.2). Prefetch cache holds 2x parallelism chunks (§1.4).
        # `is None` checks: an injected cache may be empty, and LRUCache
        # defines __len__, so truthiness would silently drop it.
        self.access_cache = (
            access_cache if access_cache is not None else LRUCache(max(1, access_cache_size))
        )
        self.prefetch_cache = (
            prefetch_cache if prefetch_cache is not None else LRUCache(2 * self.parallelization)
        )
        self.strategy = prefetch_strategy or AdaptivePrefetchStrategy(self.parallelization)

        self._lock = threading.Lock()
        # Flipped at shutdown: _blocking_result must stop resubmitting after
        # its future was cancelled by the closing reader's own sweep.
        self._closed = False
        # Prefetch strategies are stateful (stream tracking) and not required
        # to be thread-safe; concurrent positional reads reach on_access from
        # many threads at once, so the fetcher serializes strategy calls.
        self._strategy_lock = threading.Lock()
        self._in_flight: Dict[object, Future] = {}
        self._nominal_done: Dict[int, Optional[int]] = {}  # k -> actual start bit
        self.stats = FetcherStats()

        # Stage-2 resolver (kernels.engine.DeviceDecodeEngine or compatible):
        # shared across fetchers by the service layer like the executor and
        # caches; externally owned, never shut down here. The codec carries
        # it into replace_markers so stage 2 can batch across chunks.
        self.resolver = resolver
        if resolver is not None and hasattr(self.codec, "set_stage2_resolver"):
            self.codec.set_stage2_resolver(resolver)

    # ------------------------------------------------------------------
    # buffer access
    # ------------------------------------------------------------------

    def _buffer(self, start_byte: int, end_byte: int) -> Tuple[bytes, int]:
        """Return (buffer, base_byte). Zero-copy for in-memory sources."""
        whole = self.reader.view()
        if whole is not None:
            return whole, 0
        end_byte = min(end_byte, self.file_size)
        return self.reader.pread(start_byte, end_byte - start_byte), start_byte

    # ------------------------------------------------------------------
    # generic cache/in-flight plumbing
    # ------------------------------------------------------------------

    def _cache_lookup(self, key):
        # Traced misses leave a zero-duration marker span: the probe itself
        # is a dict access with nothing to time — what matters in a trace is
        # *where* the miss happened (the fetch or in-flight wait that
        # follows shows up as a sibling span with the real duration). Hits
        # record nothing at all: a warm pread probes the cache once per
        # chunk it touches, and any per-probe work here (a live span, even
        # one clock read) was the dominant per-byte tracing overhead.
        val = self._cache_lookup_raw(key)
        if val is None and _obs_trace.tracing_enabled():
            _obs_trace.record_span(
                "fetcher.cache_lookup",
                _time.perf_counter(),
                0.0,
                {"kind": key[0], "key": str(key[1]), "hit": False},
            )
        return val

    def _cache_lookup_raw(self, key):
        # One logical lookup, exactly one hit or miss fleet-wide: the access
        # probe suppresses its miss so a prefetch hit right after is not also
        # counted as an access miss (that skew deflated the aggregated
        # hit-rate in service/metrics.py). Feature-detected: a duck-typed
        # injected cache without lookup() keeps the old double-count
        # behavior rather than breaking.
        lookup = getattr(self.access_cache, "lookup", None)
        if lookup is not None:
            val = lookup(key, record_miss=False)
        else:
            val = self.access_cache.get(key)
        if val is not None:
            return val
        val = self.prefetch_cache.get(key)  # owns the hit-or-miss record
        if val is not None:
            # Promote with the recompute-cost hint intact, or the access
            # tier would rank an expensive marker-mode chunk as cheaply
            # evictable as a zlib-delegable one.
            self._insert_hinted(self.access_cache, key, val,
                                recompute_cost=self._value_cost(val))
        return val

    def _pool_submit(self, fn, *args, cost: Optional[int], priority: bool) -> Future:
        """Submit to the executor, forwarding scheduling hints when it is
        hint-aware (the service layer's TenantExecutor); a plain
        ThreadPoolExecutor gets the vanilla submit."""
        submit_hinted = getattr(self.pool, "submit_hinted", None)
        if submit_hinted is not None:
            return submit_hinted(fn, *args, cost=cost, priority=priority)
        return self.pool.submit(fn, *args)

    def _boost(self, fut: Future) -> None:
        """Upgrade an already-queued task to the priority lane (no-op for
        executors without lanes)."""
        boost = getattr(self.pool, "boost", None)
        if boost is not None:
            boost(fut)

    def _live_inflight_locked(self, key) -> Optional[Future]:
        """In-flight future for ``key``, purging cancelled leftovers.

        A queued task can be cancelled out from under the fetcher (gateway
        client disconnects sweep the batch lane; executor shutdown cancels
        everything). A cancelled task never runs ``_run_task``, so its dedup
        entry would otherwise pin a dead future forever — every later read
        of that chunk would join it and raise CancelledError.
        """
        fut = self._in_flight.get(key)
        if fut is not None and fut.cancelled():
            self._in_flight.pop(key, None)
            return None
        return fut

    def _submit(self, key, fn, *args, cost: Optional[int] = None, priority: bool = False) -> Future:
        with self._lock:
            fut = self._live_inflight_locked(key)
            if fut is not None:
                if priority:
                    # An interactive read joined an already-queued batch task
                    # (typically its own earlier prefetch): upgrade its lane
                    # or the dedup would quietly drop the priority hint.
                    self._boost(fut)
                return fut
            # Carry the submitter's trace context explicitly: a plain
            # ThreadPoolExecutor does not propagate it (the service-layer
            # FairExecutor does, and _run_task defers to it when so).
            fut = self._pool_submit(self._run_task, _obs_trace.capture(),
                                    key, fn, *args,
                                    cost=cost, priority=priority)
            self._in_flight[key] = fut
            return fut

    def _blocking_result(self, key, fn, *args, cost: Optional[int] = None):
        """Submit-and-wait with cancellation resilience: if the future we
        joined was cancelled while queued (disconnect sweep racing a dedup),
        re-submit instead of failing the innocent read — unless this fetcher
        is shutting down, in which case the cancellation IS the shutdown's
        own sweep and resubmitting would run a task against the closing
        reader (a shared executor happily accepts submissions after a
        view-scoped cancel; only the fetcher knows its reader is dying)."""
        while True:
            fut = self._submit(key, fn, *args, cost=cost, priority=True)
            try:
                return fut.result()
            except CancelledError:
                if self._closed:
                    raise
                with self._lock:
                    self._live_inflight_locked(key)  # purge the dead entry
                continue

    def _insert_hinted(self, cache, key, value, recompute_cost: int) -> None:
        """Cache insert carrying a recompute-cost hint when supported."""
        insert_hinted = getattr(cache, "insert_hinted", None)
        if insert_hinted is not None:
            insert_hinted(key, value, recompute_cost=recompute_cost)
        else:
            cache.insert(key, value)

    def _value_cost(self, value) -> int:
        """Recompute-cost estimate for an arbitrary cached value."""
        if isinstance(value, DecodeResult):
            return self._result_cost(value)
        nbytes = getattr(value, "nbytes", None)
        if nbytes is not None:
            return max(1, int(nbytes))
        try:
            return max(1, len(value))
        except TypeError:
            return 1

    def _run_task(self, ctx, key, fn, *args):
        try:
            if not _obs_trace.tracing_enabled():
                return fn(*args)
            # FairExecutor workers already reinstated the submitter's context
            # (and opened an executor.run span we should nest under); only a
            # bare pool needs the carried context attached here.
            attach_ctx = ctx if _obs_trace.current_context() is None else None
            with _obs_trace.attach(attach_ctx), _obs_trace.span(
                "fetcher.task", {"kind": key[0], "key": str(key[1])}
            ):
                return fn(*args)
        finally:
            with self._lock:
                self._in_flight.pop(key, None)

    # ------------------------------------------------------------------
    # first pass (no index): speculative parallel decompression
    # ------------------------------------------------------------------

    def nominal_index_of(self, bit_offset: int) -> int:
        return min(bit_offset // (self.chunk_size * 8), self.n_nominal - 1)

    def _nominal_stop_bit(self, k: int) -> int:
        return min((k + 1) * self.chunk_size * 8, self.total_bits)

    # Cost model for scheduling hints (estimated bytes of decompression
    # work): marker-mode two-stage decode costs >2x a zlib delegation over
    # the same span (paper §1.3) — charge it 2x the chunk size.
    MARKER_COST_FACTOR = 2

    def _nominal_cost(self) -> int:
        return self.MARKER_COST_FACTOR * self.chunk_size

    def _result_cost(self, result: DecodeResult) -> int:
        """Recompute cost of a first-pass chunk result: marker-mode chunks
        need the full two-stage pipeline again (decode + replacement);
        window-known chunks only a single custom-decoder pass."""
        factor = 1 + self.MARKER_COST_FACTOR if result.marker_mode else self.MARKER_COST_FACTOR
        return factor * max(1, result.size)

    def trigger_prefetch(self, k: int) -> None:
        """Dispatch speculative tasks per the prefetch strategy (paper §3.1:
        access triggers the prefetcher even on a cache hit). Prefetches ride
        the batch lane: they must never delay any tenant's blocking read."""
        with self._strategy_lock:
            targets = self.strategy.on_access(k)
        for j in targets:
            if j < 0 or j >= self.n_nominal:
                continue
            with self._lock:
                if j in self._nominal_done or self._live_inflight_locked(("nom", j)) is not None:
                    continue
            self._submit(
                ("nom", j), self._task_nominal, j,
                cost=self._nominal_cost(), priority=False,
            )

    def get_chunk_at(self, bit_offset: int, window: Optional[bytes] = None) -> DecodeResult:
        """Fetch the chunk starting exactly at ``bit_offset`` (first pass).

        ``window`` may carry a known window (e.g. b"" right after a gzip
        header) enabling single-stage decode; None means two-stage marker
        mode.
        """
        k = self.nominal_index_of(bit_offset)
        self.trigger_prefetch(k)

        key = ("fp", bit_offset)
        res = self._cache_lookup(key)
        if res is not None:
            # Marker-mode results are fine even when the window is known:
            # finalize_async resolves them with the supplied window.
            return res

        # A nominal prefetch covering this offset may be in flight — its
        # result is only usable if its speculative start matched exactly.
        with self._lock:
            nom_fut = self._live_inflight_locked(("nom", k))
        if nom_fut is not None:
            # About to block an interactive read on it: pull it out of the
            # batch backlog (same inversion _submit's dedup path fixes).
            self._boost(nom_fut)
            try:
                nom_res = nom_fut.result()
            except CancelledError:
                # Swept by a disconnect between our lookup and the boost:
                # fall through to a fresh exact task, like any other miss.
                nom_res = None
            if nom_res is not None and nom_res.start_bit == bit_offset:
                return nom_res
            with self._lock:
                self.stats.redispatches += 1

        # The caller blocks on this task: interactive lane, so it bypasses
        # this tenant's own queued prefetch backlog. Known window -> single
        # stage; unknown -> marker mode at 2x cost.
        cost = self.chunk_size if window is not None else self._nominal_cost()
        res = self._blocking_result(key, self._task_exact, bit_offset, window,
                                    cost=cost)
        if res is None:
            raise RapidgzipError("exact chunk decode failed at bit %d" % bit_offset)
        return res

    # -- tasks ----------------------------------------------------------

    def _margins(self, start_byte: int, stop_byte: int):
        """Yield growing (buffer, base) windows until EOF is covered."""
        margin = max(2 * self.chunk_size, 1 << 20)
        while True:
            end = min(stop_byte + margin, self.file_size)
            yield self._buffer(start_byte, end), end >= self.file_size
            if end >= self.file_size:
                return
            margin *= 4

    def _task_nominal(self, k: int) -> Optional[DecodeResult]:
        if not self.codec.supports_speculation:
            # Exact-index codecs (BGZF, zstd) never speculate: the reader
            # builds a finalized index before any read, so a stray nominal
            # dispatch just records "nothing found" without touching stats.
            with self._lock:
                self._nominal_done[k] = None
            return None
        with self._lock:
            self.stats.nominal_tasks += 1
        start_bit = k * self.chunk_size * 8
        stop_bit = self._nominal_stop_bit(k)
        if start_bit >= self.total_bits:
            with self._lock:
                self._nominal_done[k] = None
            return None

        failed: set = set()
        result: Optional[DecodeResult] = None
        for (buf, base), at_eof in self._margins(start_bit // 8, stop_bit // 8):
            base_bits = base * 8
            local_start = start_bit - base_bits
            local_stop = stop_bit - base_bits
            need_more_data = False
            for cand in self.codec.find_chunk_starts(buf, local_start, local_stop):
                if cand + base_bits in failed:
                    continue
                with self._lock:
                    self.stats.candidates_tried += 1
                try:
                    res = self.codec.decode_chunk(
                        buf,
                        cand,
                        local_stop,
                        window=None,
                        max_out=self.max_ratio * self.chunk_size,
                    )
                except EndOfStream:
                    if not at_eof:
                        need_more_data = True
                        break
                    with self._lock:
                        self.stats.false_positive_starts += 1
                    failed.add(cand + base_bits)
                    continue
                except DeflateError:
                    with self._lock:
                        self.stats.false_positive_starts += 1
                    failed.add(cand + base_bits)
                    continue
                result = _offset_result(res, base_bits)
                break
            if result is not None or not need_more_data:
                break

        with self._lock:
            self._nominal_done[k] = result.start_bit if result is not None else None
        if result is not None:
            self._insert_hinted(
                self.prefetch_cache, ("fp", result.start_bit), result,
                recompute_cost=self._result_cost(result),
            )
            with self._lock:
                if result.contains_markers():
                    self.stats.chunks_with_markers += 1
        return result

    def _task_exact(self, bit_offset: int, window: Optional[bytes]) -> DecodeResult:
        with self._lock:
            self.stats.exact_tasks += 1
        k = self.nominal_index_of(bit_offset)
        stop_bit = max(self._nominal_stop_bit(k), bit_offset + 1)
        last_err: Optional[Exception] = None
        for (buf, base), at_eof in self._margins(bit_offset // 8, stop_bit // 8):
            base_bits = base * 8
            try:
                res = self.codec.decode_chunk(
                    buf,
                    bit_offset - base_bits,
                    stop_bit - base_bits,
                    window=window,
                    max_out=self.max_ratio * self.chunk_size,
                )
            except EndOfStream as exc:
                if not at_eof:
                    last_err = exc
                    continue
                raise
            res = _offset_result(res, base_bits)
            self._insert_hinted(
                self.prefetch_cache, ("fp", bit_offset), res,
                recompute_cost=self._result_cost(res),
            )
            with self._lock:
                self._nominal_done.setdefault(k, res.start_bit)
                if res.contains_markers():
                    self.stats.chunks_with_markers += 1
            return res
        raise last_err  # pragma: no cover - loop always ends at EOF

    # ------------------------------------------------------------------
    # finalization (stage 2)
    # ------------------------------------------------------------------

    def finalize_async(
        self, result: DecodeResult, window: Optional[bytes], out_start: int
    ) -> FinalizedChunk:
        """Propagate the window (sequential, O(32 KiB)) and dispatch full
        marker replacement to the pool."""
        window_out = self.codec.propagate_window(result.data, window)
        fc = FinalizedChunk(
            start_bit=result.start_bit,
            end_bit=result.end_bit,
            out_start=out_start,
            size=result.size,
            window_in=window,
            window_out=window_out,
            result=result,
        )
        fc._crc32 = self.crc32
        if result.marker_mode:
            # Replacement sits on the read critical path (the caller's
            # bytes() blocks on it): interactive lane, cost ~ one linear
            # pass over the chunk's output.
            fc._bytes_future = self._pool_submit(
                self._task_replace, result, window,
                cost=max(1, result.size), priority=True,
            )
        else:
            fc._bytes = result.data
        with self._lock:
            self.stats.bytes_decompressed += result.size
        return fc

    def _task_replace(self, result: DecodeResult, window: Optional[bytes]) -> np.ndarray:
        if not result.contains_markers():
            return result.data.astype(np.uint8)
        if self.resolver is not None:
            # Direct submission (not via the codec shim): many pool workers
            # hit this concurrently and the engine coalesces their chunks
            # into one batched device dispatch.
            return self.resolver.replace_markers(result.data, window)
        return self.codec.replace_markers(result.data, window)

    def crc32(self, data) -> int:
        """CRC32 through the stage-2 resolver when present, zlib otherwise.

        Accepts bytes or a uint8 ndarray (reader verification passes array
        segments straight through).
        """
        if self.resolver is not None:
            return self.resolver.crc32(data)
        if isinstance(data, np.ndarray):
            data = data.tobytes()
        return _zlib.crc32(data) & 0xFFFFFFFF

    # ------------------------------------------------------------------
    # indexed mode (second pass / imported index / BGZF)
    # ------------------------------------------------------------------

    def _indexed_cost(self, i: int) -> int:
        out_size = self.index.chunk_output_size(i)
        return out_size if out_size else self.chunk_size

    def get_indexed(self, i: int) -> np.ndarray:
        """Decompressed bytes of index chunk ``i`` (seek point i .. i+1)."""
        with self._strategy_lock:
            targets = self.strategy.on_access(i)
        for j in targets:
            if 0 <= j < len(self.index) and self.index.chunk_output_size(j) is not None:
                with self._lock:
                    if self._live_inflight_locked(("ix", j)) is not None:
                        continue
                if ("ix", j) in self.prefetch_cache or ("ix", j) in self.access_cache:
                    continue
                self._submit(("ix", j), self._task_indexed, j,
                             cost=self._indexed_cost(j), priority=False)

        key = ("ix", i)
        val = self._cache_lookup(key)
        if val is not None:
            return val
        # Blocking fetch: interactive lane (jumps this tenant's prefetches),
        # resilient to a disconnect sweep cancelling the future it joined.
        return self._blocking_result(key, self._task_indexed, i,
                                     cost=self._indexed_cost(i))

    def put_indexed(self, i: int, data: np.ndarray) -> None:
        """Install first-pass bytes under their index key (frontier handoff).

        Goes to the prefetch cache (2x parallelism entries): the access cache
        may be sized 1 and a chunk can hand over several split slices.
        """
        self._insert_hinted(self.prefetch_cache, ("ix", i), data,
                            recompute_cost=int(data.nbytes))

    def _task_indexed(self, i: int) -> np.ndarray:
        with self._lock:
            self.stats.indexed_tasks += 1
        point = self.index.point_at(i)
        out_size = self.index.chunk_output_size(i)
        if out_size is None:
            raise RapidgzipError("indexed chunk %d has unknown size" % i)
        if out_size == 0:
            return np.empty(0, dtype=np.uint8)
        start_byte = point.compressed_bit // 8
        if i + 1 < len(self.index):
            comp_span = self.index.point_at(i + 1).compressed_bit // 8 - start_byte
        else:
            comp_span = self.file_size - start_byte
        buf, base = self._buffer(start_byte, start_byte + comp_span + (1 << 16))
        local_bit = point.compressed_bit - base * 8
        if i + 1 < len(self.index):
            local_stop = self.index.point_at(i + 1).compressed_bit - base * 8
        else:
            local_stop = len(buf) * 8

        if point.flags & self.codec.decoder_required_flags:
            # Deflate: a gzip member boundary inside the chunk (zlib raw
            # streams cannot cross it) or stored-block padding that would
            # not survive the bit-shift realignment — use the codec's own
            # decoder (window known -> single stage). Codecs whose delegate
            # always works declare an empty mask and never take this branch.
            res = self.codec.decode_chunk(
                buf,
                local_bit,
                local_stop,
                window=point.window if point.window is not None else b"",
                max_out=out_size + self.codec.window_size,
            )
            data = res.data[:out_size]
            if data.shape[0] < out_size:
                raise DeflateError("indexed chunk %d produced too few bytes" % i)
            # Custom-decoder path: ~2x the recompute cost of a delegation.
            self._insert_hinted(self.prefetch_cache, ("ix", i), data,
                                recompute_cost=self.MARKER_COST_FACTOR * out_size)
            return data

        with self._lock:
            # Historical stats name, kept across codecs: "delegation" = the
            # native-library fast path (zlib for deflate, zstd for zstd).
            self.stats.zlib_delegations += 1
        raw = self.codec.delegate(
            buf, local_bit, point.window or b"", out_size,
            # +2 bytes slack: enough for the final block's bit tail, not
            # enough for zlib to parse a (shift-broken) stored header beyond
            # the chunk boundary.
            max_input_bytes=comp_span + 2,
        )
        data = np.frombuffer(raw, dtype=np.uint8)
        # zlib-delegable: the cheapest entry class in the pool — recompute
        # is a single delegation over out_size bytes.
        self._insert_hinted(self.prefetch_cache, ("ix", i), data,
                            recompute_cost=out_size)
        return data

    # ------------------------------------------------------------------

    def shutdown(self) -> None:
        self._closed = True  # before the sweep: see _blocking_result
        if self._owns_executor:
            self.pool.shutdown(wait=False, cancel_futures=True)
        else:
            # Externally owned executor: never shut it down, but drop our own
            # queued tasks if the executor offers a scoped cancel (the
            # service layer's TenantExecutor view does) — otherwise stale
            # prefetches would run against a closing reader.
            cancel_pending = getattr(self.pool, "cancel_pending", None)
            if cancel_pending is not None:
                cancel_pending()
        # Injected caches may outlive this fetcher inside a shared pool;
        # release() deregisters them and returns their bytes to the budget.
        for cache in (self.access_cache, self.prefetch_cache):
            release = getattr(cache, "release", None)
            if release is not None:
                release()

    def cache_report(self) -> dict:
        def stats_of(cache) -> dict:
            # Same duck-typed contract as the lookup/insert hooks: a cache
            # without the atomic snapshot() still reports via .stats.
            snapshot = getattr(cache, "snapshot", None)
            if snapshot is not None:
                return snapshot()["stats"].as_dict()
            return cache.stats.as_dict()

        return {
            "access": stats_of(self.access_cache),
            "prefetch": stats_of(self.prefetch_cache),
            "fetcher": self.stats.as_dict(),
        }


#: Historical name from when the fetcher was deflate-only; the class has
#: been codec-parameterized (``codec=`` kwarg) but the default construction
#: is unchanged, so existing callers keep working.
GzipChunkFetcher = ChunkFetcher


def _offset_result(res: DecodeResult, base_bits: int) -> DecodeResult:
    """Translate a buffer-local DecodeResult to global bit offsets."""
    if base_bits == 0:
        return res
    res.start_bit += base_bits
    res.end_bit += base_bits
    for b in res.blocks:
        b.bit_offset += base_bits
    for me in res.member_ends:
        me.footer_end_bit += base_bits
    for ms in res.member_starts:
        ms.header_start_bit += base_bits
        ms.deflate_start_bit += base_bits
    return res
