"""Codec interface: format-specific machinery behind the chunk/index model.

Everything above the chunk fetcher — caches, scheduler, index store, server,
gateway, fleet — treats an archive as *chunks addressed by an index*: a
sorted list of seek points ``(compressed bit offset, decompressed byte
offset, window, flags)`` plus per-chunk decompressed sizes. How those chunks
come to exist, and how their bytes are produced, is the codec's business.
This module defines that contract and ships three implementations that
exercise its opposite corners:

  * ``DeflateCodec`` — the paper's hard case (gzip / raw deflate). Chunk
    starts must be *guessed* by a block finder and confirmed by trial
    decompression (speculative first pass, paper §3.4); decoding without a
    known 32 KiB window runs in two-stage marker mode (§2.2); once a seek
    point exists, decompression is delegated to zlib (§1.3).
  * ``BgzfCodec`` — the trivially-parallel case (paper §3.4.4). The BC
    FEXTRA subfield gives every member's exact compressed size, so
    ``build_exact_index`` produces a complete, finalized index from a pure
    metadata walk: zero speculative decoding, zero marker passes. Inside a
    member it is plain deflate, so decode/delegate are inherited.
  * ``ZstdCodec`` — the format-native case (ACEAPEX direction). The zstd
    seekable format's seek-table footer enumerates independent frames with
    exact compressed+decompressed sizes; frames map 1:1 onto index chunks,
    ``window_size`` is 0, and decoding is always a native-library call.

## The codec contract

A ``Codec`` must provide:

``tag``
    Short stable string serialized into index blobs (``GzipIndex.codec_tag``)
    and mixed into ``IndexStore.file_identity`` keys. Never reuse a tag for
    incompatible chunk semantics.
``window_size``
    Bytes of preceding history a seek point must carry for mid-stream
    decoding (32768 for deflate, 0 for formats with independent chunks).
``probe(head)``
    True if ``head`` (the first few KiB of the file) looks like this codec's
    format. Probes must be order-robust: ``detect_codec`` consults the most
    specific codec first (BGZF before plain gzip, since BGZF *is* gzip) and
    a probe must never raise on another format's bytes.
``leading_header_bits(reader)``
    Bit offset where the first chunk's payload starts (after any leading
    container header). Only called when a speculative first pass will run.
``build_exact_index(reader, index)``
    Metadata-only construction of a complete index. Return True after
    populating and *finalizing* ``index`` (the reader then skips the
    speculative pass entirely); return False when the format offers no such
    shortcut. May raise ``FormatError`` on malformed metadata — the reader
    falls back to the speculative pass when the codec supports one.
``find_chunk_starts(buf, start_bit, stop_bit)``
    Iterator of candidate chunk-start bit offsets inside ``buf`` (the
    speculative finder). Only required when ``supports_speculation``.
``decode_chunk(buf, start_bit, stop_bit, *, window, max_out)``
    Decode one chunk to a ``DecodeResult``. ``window=None`` requests
    two-stage marker mode (only meaningful for marker codecs);
    ``window=b""`` / bytes requests exact single-stage output.
``delegate(buf, start_bit, window, out_size, *, max_input_bytes)``
    Native-library fast path producing exactly ``out_size`` bytes from a
    seek point. Raise ``FormatError`` when impossible; the fetcher consults
    ``decoder_required_flags`` first so it normally never is.
``decoder_required_flags``
    Seek-point flag mask for which ``delegate`` is invalid and
    ``decode_chunk`` must be used (deflate: interior member ends, shift-
    broken stored blocks).
``propagate_window(data, window)`` / ``replace_markers(data, window)``
    Stage-2 marker machinery; windowless codecs inherit the no-op defaults.
``set_stage2_resolver(resolver)``
    Optional pluggable stage-2 back end (``kernels.engine``): when set,
    marker resolution routes through it (batched device dispatch with CPU
    crossover); output stays bit-identical either way.
``split_candidate(block)``
    For marker codecs: may the on-the-fly indexer place an interior seek
    point at this block boundary? Returns ``(bit_offset, flags)`` or None.
``index_compatible_tags``
    Index ``codec_tag`` values this codec can serve. Legacy (pre-tag) index
    blobs import as ``"deflate"``; BGZF accepts those because its members
    are deflate-delegable.

## How chunk/index semantics map per codec

=============  =====================  =========================  ==========
codec          seek point sits at     chunk payload              window
=============  =====================  =========================  ==========
``deflate``    any deflate block      raw deflate, bit-aligned   32 KiB
               boundary (bit offset)
``bgzf``       first deflate bit      raw deflate of one member  b"" always
               after a member header
``zstd``       frame start (byte-     one complete zstd frame    none
               aligned, incl. the     (magic + blocks + opt.
               frame header)          checksum)
=============  =====================  =========================  ==========

## Checklist for adding a fourth codec

1. Pick a ``tag`` and decide ``window_size`` (0 if chunks are independent).
2. Implement ``probe`` + register the class in ``CODECS`` (and in
   ``_DETECTION_ORDER`` *before* any codec whose format yours embeds).
3. Implement ``build_exact_index`` if the format carries chunk metadata
   (sizes in headers/footers); otherwise implement ``find_chunk_starts`` +
   marker-mode ``decode_chunk`` and set ``supports_speculation = True``.
4. Implement ``delegate`` (the hot path for indexed reads) and declare
   ``decoder_required_flags`` for the cases it cannot handle.
5. Add a compressor to ``core.synth`` so tests/benchmarks can generate
   corpora offline, then extend the ``codec_case`` fixture in
   ``tests/conftest.py`` — the reader/pread round-trip suite and the
   ``codecs`` benchmark section pick the new codec up automatically.
6. Nothing above the fetcher should need changes; if it does, the new
   codec's semantics leaked — push them back down behind this interface.
"""

from __future__ import annotations

import struct
from typing import Iterable, Iterator, List, Optional, Tuple, Union

import numpy as np

from .bitreader import BitReader
from .deflate import (
    BT_DYNAMIC,
    BT_STORED,
    WINDOW_SIZE,
    BlockBoundary,
    DecodeResult,
    DeflateChunkDecoder,
    canonical_stored_offset,
)
from .errors import FormatError, GzipHeaderError
from .gzip_format import parse_gzip_header, scan_bgzf_members
from .index import (
    FLAG_STORED_BLOCK,
    FLAG_STREAM_START,
    GzipIndex,
    SeekPoint,
)
from .markers import propagate_window as _propagate_window
from .markers import replace_markers as _replace_markers


class Codec:
    """Format plug-in for the chunk fetcher / reader (contract above).

    The base class implements the windowless, non-speculative defaults so a
    metadata-indexed codec only needs ``probe``/``build_exact_index``/
    ``delegate``.
    """

    tag: str = "abstract"
    window_size: int = 0
    supports_speculation: bool = False
    #: reader verifies per-member CRC32/ISIZE from DecodeResult.member_ends
    verifies_members: bool = False
    #: seek-point flags that force decode_chunk over delegate
    decoder_required_flags: int = 0
    #: optional stage-2 resolver (duck-typed: ``replace_markers``/``crc32``,
    #: e.g. ``kernels.engine.DeviceDecodeEngine``); None = host CPU path.
    stage2_resolver = None

    def set_stage2_resolver(self, resolver) -> None:
        """Route stage-2 marker resolution through ``resolver`` (or back to
        the CPU with None). The resolver decides device-vs-CPU per request;
        the codec contract (bit-identical output) is unchanged."""
        self.stage2_resolver = resolver

    @property
    def index_compatible_tags(self) -> frozenset:
        return frozenset((self.tag,))

    # -- detection / setup --------------------------------------------------

    def probe(self, head: bytes) -> bool:
        raise NotImplementedError

    def leading_header_bits(self, reader) -> int:
        raise FormatError("%s codec has no speculative first pass" % self.tag)

    def build_exact_index(self, reader, index: GzipIndex) -> bool:
        return False

    # -- speculative first pass --------------------------------------------

    def find_chunk_starts(self, buf, start_bit: int, stop_bit: int) -> Iterator[int]:
        raise FormatError("%s codec cannot speculate chunk starts" % self.tag)

    def decode_chunk(
        self,
        buf,
        start_bit: int,
        stop_bit: Optional[int] = None,
        *,
        window: Optional[bytes] = None,
        max_out: Optional[int] = None,
    ) -> DecodeResult:
        raise NotImplementedError

    # -- indexed fast path --------------------------------------------------

    def delegate(
        self,
        buf,
        start_bit: int,
        window: bytes,
        out_size: int,
        *,
        max_input_bytes: Optional[int] = None,
    ) -> bytes:
        raise NotImplementedError

    # -- stage-2 marker machinery (no-ops for windowless codecs) -----------

    def propagate_window(self, data: np.ndarray, window: Optional[bytes]) -> bytes:
        return b""

    def replace_markers(self, data: np.ndarray, window: Optional[bytes]) -> np.ndarray:
        if data.dtype != np.uint8:
            return data.astype(np.uint8)
        return data

    # -- on-the-fly index splitting ----------------------------------------

    def split_candidate(self, block: BlockBoundary) -> Optional[Tuple[int, int]]:
        """(bit offset, extra point flags) if an interior seek point may be
        placed at this block boundary, else None."""
        return None

    def stored_block_offsets(self, result: DecodeResult) -> List[int]:
        """Chunk-local output offsets of stored (uncompressed) blocks — the
        spans whose padding makes bit-shifted delegation unsafe
        (FLAG_ZLIB_UNSAFE). Empty for codecs without the concept."""
        return []

    # -- seek hostility (transcode trigger) ---------------------------------

    def seek_hostility(self, index: GzipIndex) -> float:
        """How seek-hostile did the archive prove during its first pass?

        Returns a score in [0, 1]; the transcode layer re-encodes archives
        scoring above its threshold as a parallel-friendly twin (BGZF /
        zstd-seekable). The base implementation — and any codec whose index
        comes from framing metadata alone — reports 0.0: such formats are
        already O(1)-seekable. Scores are computed from the in-memory
        ``index.observations`` the reader records while building the index,
        so only a freshly *built* index (first full decompression) can
        probe hostile; imported/warm indexes score 0.0.
        """
        return 0.0

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return "<%s tag=%r>" % (type(self).__name__, self.tag)


# ---------------------------------------------------------------------------
# Deflate (gzip / raw) — the paper's speculative two-stage machinery
# ---------------------------------------------------------------------------

#: Largest leading gzip header accepted: FEXTRA (2+65535) + FNAME and
#: FCOMMENT (64 KiB each, the parser's own cap) + fixed fields fit well
#: under 1 MiB; anything bigger is malformed, not merely large.
_MAX_HEADER_BYTES = 1 << 20


class DeflateCodec(Codec):
    """gzip / raw deflate: speculative block finding + two-stage decode."""

    tag = "deflate"
    window_size = WINDOW_SIZE
    supports_speculation = True

    def __init__(self, framing: str = "gzip"):
        if framing not in ("gzip", "raw"):
            raise ValueError("framing must be 'gzip' or 'raw'")
        self.framing = framing
        self.verifies_members = framing == "gzip"

    @property
    def decoder_required_flags(self) -> int:  # type: ignore[override]
        from .index import FLAG_HAS_INTERIOR_MEMBER_END, FLAG_ZLIB_UNSAFE

        return FLAG_HAS_INTERIOR_MEMBER_END | FLAG_ZLIB_UNSAFE

    @property
    def index_compatible_tags(self) -> frozenset:
        # BGZF indexes are deflate-delegable (byte-aligned member starts,
        # empty windows), so a deflate reader can serve one and vice versa.
        return frozenset(("deflate", "bgzf"))

    def probe(self, head: bytes) -> bool:
        return len(head) >= 2 and head[0] == 0x1F and head[1] == 0x8B

    def leading_header_bits(self, reader) -> int:
        if self.framing == "raw":
            return 0
        # A fixed-size pread truncates headers with large FEXTRA/FNAME
        # fields; on a truncation (EndOfStream under the parser's
        # GzipHeaderError) retry with a doubled read while the file still
        # has bytes to give, capped with a clean error.
        from .errors import EndOfStream

        read_size = 1 << 16
        while True:
            head = reader.pread(0, read_size)
            try:
                hdr = parse_gzip_header(BitReader(head))
            except GzipHeaderError as exc:
                truncated = isinstance(exc.__cause__, EndOfStream)
                if truncated and len(head) == read_size:
                    if read_size >= _MAX_HEADER_BYTES:
                        raise GzipHeaderError(
                            "gzip header exceeds %d bytes" % _MAX_HEADER_BYTES
                        ) from exc
                    read_size *= 2
                    continue
                raise
            return hdr.header_bits

    def find_chunk_starts(self, buf, start_bit: int, stop_bit: int) -> Iterator[int]:
        from .block_finder import CombinedBlockFinder

        return iter(CombinedBlockFinder(buf, start_bit, stop_bit))

    def decode_chunk(
        self,
        buf,
        start_bit: int,
        stop_bit: Optional[int] = None,
        *,
        window: Optional[bytes] = None,
        max_out: Optional[int] = None,
    ) -> DecodeResult:
        decoder = DeflateChunkDecoder(buf, framing=self.framing)
        return decoder.decode_chunk(start_bit, stop_bit, window=window, max_out=max_out)

    def delegate(
        self,
        buf,
        start_bit: int,
        window: bytes,
        out_size: int,
        *,
        max_input_bytes: Optional[int] = None,
    ) -> bytes:
        from .zlib_bridge import zlib_inflate_at

        return zlib_inflate_at(
            buf, start_bit, window, out_size, max_input_bytes=max_input_bytes
        )

    def propagate_window(self, data: np.ndarray, window: Optional[bytes]) -> bytes:
        return _propagate_window(data, window)

    def replace_markers(self, data: np.ndarray, window: Optional[bytes]) -> np.ndarray:
        if self.stage2_resolver is not None and data.dtype != np.uint8:
            return self.stage2_resolver.replace_markers(data, window)
        return _replace_markers(data, window)

    def split_candidate(self, block: BlockBoundary) -> Optional[Tuple[int, int]]:
        # The finder can only resume at Dynamic or Non-Compressed blocks;
        # stored blocks use the canonical offset (padding ambiguity, paper
        # §3.4.1) and carry the flag so importers know.
        if block.block_type not in (BT_STORED, BT_DYNAMIC):
            return None
        if block.block_type == BT_STORED:
            return canonical_stored_offset(block.bit_offset), FLAG_STORED_BLOCK
        return block.bit_offset, 0

    def stored_block_offsets(self, result: DecodeResult) -> List[int]:
        return [b.out_offset for b in result.blocks if b.block_type == BT_STORED]

    def seek_hostility(self, index: GzipIndex) -> float:
        """Deflate hostility from first-pass observations (paper §4.8).

        Three signals, strongest wins:

        * **fixed-only members** — chunks whose every block is
          fixed-Huffman are invisible to the block finder; their fraction
          is the score (1.0 for a ``Z_FIXED`` archive).
        * **no block splits found** — speculation never landed a single
          chunk (no marker-mode chunk collected) *and* no interior split
          point was recorded: the whole first pass degraded to a
          sequential chain of exact tasks. Scores 0.9.
        * **two-stage-only point fraction** — seek points whose flags
          require the marker decoder forever (``decoder_required_flags``:
          interior member ends, zlib-unsafe stored spans). When ≥90% of
          points are stuck on the 2x two-stage path every cache recompute
          pays double, but random access still parallelizes — so this
          signal scores 0.5 × fraction, below the default transcode
          threshold on its own (it raises the score of an archive that is
          *also* split-starved, never condemns a healthy one: ordinary
          gzip of incompressible data hits it via stored-block
          realignment).
        """
        obs = getattr(index, "observations", None) or {}
        chunks = int(obs.get("chunks", 0))
        if not index.finalized or chunks <= 0:
            return 0.0
        score = float(obs.get("fixed_chunks", 0)) / chunks
        if (
            chunks >= 2
            and not obs.get("marker_chunks", 0)
            and not obs.get("split_points", 0)
        ):
            score = max(score, 0.9)
        points = index.points()
        if points:
            required = self.decoder_required_flags
            hard = sum(1 for p in points if p.flags & required)
            hard_frac = hard / len(points)
            if hard_frac >= 0.9:
                score = max(score, 0.5 * hard_frac)
        return min(1.0, score)


class BgzfCodec(DeflateCodec):
    """BGZF: exact member sizes from the BC FEXTRA subfield (paper §3.4.4).

    ``build_exact_index`` walks member headers via metadata alone and emits
    one finalized seek point per member — a cold open does zero speculative
    decoding and zero marker passes. Decoding inherits deflate (a BGZF
    member body is a raw deflate stream; seek points are byte-aligned with
    empty windows, so every chunk is zlib-delegable).
    """

    tag = "bgzf"

    def __init__(self):
        super().__init__(framing="gzip")

    @property
    def index_compatible_tags(self) -> frozenset:
        # Legacy (pre-tag) blobs import as "deflate"; older sessions also
        # built BGZF indexes under that tag — both decode identically here.
        return frozenset(("bgzf", "deflate"))

    def probe(self, head: bytes) -> bool:
        # The BC subfield, not just gzip magic: plain gzip with an unrelated
        # FEXTRA field must NOT probe as BGZF (it lacks member sizes).
        if not super().probe(head):
            return False
        try:
            return parse_gzip_header(BitReader(head)).is_bgzf
        except GzipHeaderError:
            return False

    def build_exact_index(self, reader, index: GzipIndex) -> bool:
        members = scan_bgzf_members(reader)
        out = 0
        for offset, size in members:
            head = reader.pread(offset, min(size, 1 << 12))
            hdr = parse_gzip_header(BitReader(head))
            footer = reader.pread(offset + size - 8, 8)
            isize = int.from_bytes(footer[4:8], "little")
            if isize == 0:
                continue  # BGZF EOF marker block
            index.add_point(
                SeekPoint(offset * 8 + hdr.header_bits, out, b"", FLAG_STREAM_START)
            )
            out += isize
        index.finalize(out, reader.size())
        return True

    def seek_hostility(self, index: GzipIndex) -> float:
        # Inherits DeflateCodec, but a BGZF index comes from framing
        # metadata alone: member boundaries are O(1)-seekable by
        # construction, so the deflate heuristics (which would misread the
        # zero-marker/zero-split profile as sequential degradation) never
        # apply. BGZF is the transcode *target*, never a source.
        return 0.0


# ---------------------------------------------------------------------------
# Zstandard (seekable format) — native frames, no windows, no speculation
# ---------------------------------------------------------------------------

_ZSTD_FRAME_MAGIC = 0xFD2FB528
_ZSTD_SKIPPABLE_MIN = 0x184D2A50
_ZSTD_SKIPPABLE_MAX = 0x184D2A5F
_ZSTD_SEEKABLE_SKIPPABLE = 0x184D2A5E  # seek-table skippable frame magic
_ZSTD_SEEKABLE_MAGIC = 0x8F92EAB1  # last 4 bytes of a seekable file


def zstd_backend():
    """The available zstd implementation, or None.

    Prefers the stdlib ``compression.zstd`` (Python 3.14+), falls back to
    the optional ``zstandard`` package. Both expose ``ZstdCompressor`` /
    ``ZstdDecompressor`` with compatible one-shot APIs; the returned shim
    normalizes the two call signatures.
    """
    try:
        from compression import zstd as _stdlib_zstd  # type: ignore

        class _StdlibShim:
            name = "compression.zstd"

            @staticmethod
            def compress(data: bytes, level: int = 3) -> bytes:
                return _stdlib_zstd.compress(data, level)

            @staticmethod
            def decompress_frame(data: bytes) -> bytes:
                # One frame only: trailing bytes beyond it are ignored.
                d = _stdlib_zstd.ZstdDecompressor()
                return d.decompress(data)

        return _StdlibShim
    except ImportError:
        pass
    try:
        import zstandard as _zstandard  # type: ignore

        class _ZstandardShim:
            name = "zstandard"

            @staticmethod
            def compress(data: bytes, level: int = 3) -> bytes:
                return _zstandard.ZstdCompressor(level=level).compress(data)

            @staticmethod
            def decompress_frame(data: bytes) -> bytes:
                # decompressobj stops cleanly at the frame end, tolerating
                # trailing bytes from the next frame in the same buffer.
                return _zstandard.ZstdDecompressor().decompressobj().decompress(data)

        return _ZstandardShim
    except ImportError:
        return None


def have_zstd() -> bool:
    return zstd_backend() is not None


def parse_zstd_seek_table(reader) -> List[Tuple[int, int, int]]:
    """[(frame_byte_offset, compressed_size, decompressed_size), ...].

    Parses the seekable-format footer: the file's final skippable frame
    carries N ``(compressed_size, decompressed_size[, checksum])`` entries
    followed by ``(frame_count: u32, descriptor: u8, 0x8F92EAB1: u32)``.
    Raises FormatError when the footer is absent or inconsistent.
    """
    size = reader.size()
    if size < 17:  # skippable header (8) + footer (9)
        raise FormatError("file too small for a zstd seek table")
    foot = reader.pread(size - 9, 9)
    n_frames, descriptor, magic = struct.unpack("<IBI", foot)
    if magic != _ZSTD_SEEKABLE_MAGIC:
        raise FormatError("zstd source has no seekable seek table")
    if descriptor & 0x7C:  # reserved bits must be zero
        raise FormatError("zstd seek table has reserved descriptor bits set")
    entry_size = 12 if descriptor & 0x80 else 8
    payload = n_frames * entry_size + 9
    table_start = size - payload - 8
    if table_start < 0:
        raise FormatError("zstd seek table larger than the file")
    head = reader.pread(table_start, 8)
    skip_magic, skip_size = struct.unpack("<II", head)
    if skip_magic != _ZSTD_SEEKABLE_SKIPPABLE or skip_size != payload:
        raise FormatError("zstd seek table framing is inconsistent")
    entries_raw = reader.pread(table_start + 8, n_frames * entry_size)
    if len(entries_raw) != n_frames * entry_size:
        raise FormatError("truncated zstd seek table")
    frames: List[Tuple[int, int, int]] = []
    comp_off = 0
    for i in range(n_frames):
        comp_size, dec_size = struct.unpack_from("<II", entries_raw, i * entry_size)
        frames.append((comp_off, comp_size, dec_size))
        comp_off += comp_size
    if comp_off != table_start:
        raise FormatError(
            "zstd seek table covers %d bytes but frames end at %d"
            % (comp_off, table_start)
        )
    return frames


class ZstdCodec(Codec):
    """Zstd seekable format: frames ARE chunks; the index IS the seek table.

    Opposite corner of the interface from deflate: no speculation, no
    markers, ``window_size == 0`` (frames are independent), every chunk
    decoded by one native-library call. Requires ``compression.zstd``
    (3.14+) or the optional ``zstandard`` package at decode time; ``probe``
    works without either.
    """

    tag = "zstd"
    window_size = 0
    supports_speculation = False
    verifies_members = False  # the library verifies per-frame checksums

    def probe(self, head: bytes) -> bool:
        if len(head) < 4:
            return False
        magic = struct.unpack_from("<I", head, 0)[0]
        return magic == _ZSTD_FRAME_MAGIC or (
            _ZSTD_SKIPPABLE_MIN <= magic <= _ZSTD_SKIPPABLE_MAX
        )

    def _backend(self):
        backend = zstd_backend()
        if backend is None:
            raise FormatError(
                "zstd source needs the 'compression.zstd' stdlib module "
                "(Python 3.14+) or the optional 'zstandard' package"
            )
        return backend

    def build_exact_index(self, reader, index: GzipIndex) -> bool:
        self._backend()  # fail early with a clear error, before any decode
        frames = parse_zstd_seek_table(reader)
        out = 0
        for comp_off, comp_size, dec_size in frames:
            if dec_size == 0:
                continue  # skippable or empty frame: nothing addressable
            index.add_point(SeekPoint(comp_off * 8, out, b"", FLAG_STREAM_START))
            out += dec_size
        index.finalize(out, reader.size())
        return True

    def decode_chunk(
        self,
        buf,
        start_bit: int,
        stop_bit: Optional[int] = None,
        *,
        window: Optional[bytes] = None,
        max_out: Optional[int] = None,
    ) -> DecodeResult:
        if start_bit % 8:
            raise FormatError("zstd frames are byte-aligned")
        stop_byte = len(buf) if stop_bit is None else (stop_bit + 7) // 8
        raw = self.delegate_bytes(buf, start_bit // 8, stop_byte)
        if max_out is not None and len(raw) > max_out:
            raise FormatError("zstd frame output exceeds max_out=%d" % max_out)
        data = np.frombuffer(raw, dtype=np.uint8)
        res = DecodeResult(
            start_bit=start_bit,
            end_bit=stop_byte * 8,
            data=data,
            marker_mode=False,
        )
        res.ended_at_eos = stop_byte >= len(buf)
        return res

    def delegate(
        self,
        buf,
        start_bit: int,
        window: bytes,
        out_size: int,
        *,
        max_input_bytes: Optional[int] = None,
    ) -> bytes:
        if start_bit % 8:
            raise FormatError("zstd frames are byte-aligned")
        start = start_bit // 8
        stop = len(buf) if max_input_bytes is None else min(len(buf), start + max_input_bytes)
        raw = self.delegate_bytes(buf, start, stop)
        if len(raw) < out_size:
            raise FormatError(
                "zstd frame produced %d of %d bytes" % (len(raw), out_size)
            )
        return raw[:out_size]

    def delegate_bytes(self, buf, start_byte: int, stop_byte: int) -> bytes:
        backend = self._backend()
        return backend.decompress_frame(bytes(buf[start_byte:stop_byte]))


# ---------------------------------------------------------------------------
# Registry + detection
# ---------------------------------------------------------------------------

#: tag -> zero-arg factory. ``resolve_codec`` also accepts "raw" as an alias
#: for raw-framed deflate.
CODECS = {
    "deflate": DeflateCodec,
    "bgzf": BgzfCodec,
    "zstd": ZstdCodec,
}

#: Most specific first: BGZF is a strict subset of gzip, so it must probe
#: before plain deflate; zstd's magic collides with neither.
_DETECTION_ORDER = ("bgzf", "zstd", "deflate")


def detect_codec(head: bytes) -> Codec:
    """Codec for a file starting with ``head`` (first few KiB).

    Detection never raises on valid input of any known format: each probe
    is consulted in most-specific-first order and a probe exception counts
    as "not mine". Unknown bytes fall back to ``DeflateCodec`` — the reader
    then produces the same clean GzipHeaderError it always has.
    """
    for tag in _DETECTION_ORDER:
        codec = CODECS[tag]()
        try:
            if codec.probe(head):
                return codec
        except Exception:
            continue
    return DeflateCodec()


def detect_codec_tag(source) -> str:
    """Cheap codec tag for an arbitrary source (path / bytes / FileReader).

    Reads at most 4 KiB of head bytes. Any probe failure degrades to
    "deflate" — identity keys must be computable for malformed sources too
    (the open that follows reports the real error).
    """
    try:
        head = _head_bytes(source)
    except Exception:
        return DeflateCodec.tag
    return detect_codec(head).tag


def _head_bytes(source, n: int = 1 << 12) -> bytes:
    import os

    if isinstance(source, (bytes, bytearray, memoryview)):
        return bytes(source[:n])
    if hasattr(source, "pread"):  # FileReader duck type
        return source.pread(0, n)
    if isinstance(source, (str, os.PathLike)):
        with open(os.fspath(source), "rb") as f:
            return f.read(n)
    if hasattr(source, "read") and hasattr(source, "seek"):
        pos = source.tell()
        try:
            source.seek(0)
            return source.read(n)
        finally:
            source.seek(pos)
    raise TypeError("cannot probe codec for %r" % type(source))


def resolve_codec(codec: Union[None, str, Codec], *, framing: str = "gzip",
                  head: Optional[bytes] = None) -> Codec:
    """Normalize a codec argument (instance, tag, or None=auto-detect)."""
    if isinstance(codec, Codec):
        return codec
    if isinstance(codec, str):
        if codec == "raw":
            return DeflateCodec(framing="raw")
        try:
            factory = CODECS[codec]
        except KeyError:
            raise ValueError(
                "unknown codec %r (known: %s)" % (codec, ", ".join(sorted(CODECS)))
            ) from None
        if factory is DeflateCodec:
            return DeflateCodec(framing=framing)
        return factory()
    if framing == "raw":
        return DeflateCodec(framing="raw")
    if head is not None:
        return detect_codec(head)
    return DeflateCodec(framing=framing)
