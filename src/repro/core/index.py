"""Seek-point index (paper §1.3 "Index for Seeking", §3.3).

Each seek point stores (compressed bit offset, decompressed byte offset, the
32 KiB window preceding it, flags). Decompression can resume at any point
with no work before it; offsets between points cost at most one point
spacing of sequential decode. The index is built *on the fly* during the
first pass (not a preprocessing step), can be exported/imported (like
indexed_gzip), rebalances chunk sizes for the second pass (equal
decompressed spacing -> load balance), and enables zlib delegation.

Windows are stored zlib-compressed — with default spacing the raw windows
would often dominate the index size.
"""

from __future__ import annotations

import io
import json
import struct
import threading
import zlib
from bisect import bisect_right
from dataclasses import dataclass
from typing import BinaryIO, List, Optional, Union

from .deflate import WINDOW_SIZE
from .errors import IndexError_

#: v1 (legacy): no codec tag in the meta header — imports as "deflate".
_MAGIC_V1 = b"RPGZIDX1"
#: v2: JSON meta carries ``"codec": <tag>``; point records are unchanged.
_MAGIC_V2 = b"RPGZIDX2"
_MAGIC = _MAGIC_V2

FLAG_STREAM_START = 1  # point sits right after a gzip member header
FLAG_HAS_INTERIOR_MEMBER_END = 2  # chunk [this, next) contains a member footer
FLAG_STORED_BLOCK = 4  # point is the canonical offset of a stored block
#: zlib delegation is only valid when stored-block padding survives the bit
#: shift: the chunk must start byte-aligned or contain no stored blocks
#: (stored blocks re-derive their padding from zlib's own byte alignment).
FLAG_ZLIB_UNSAFE = 8


@dataclass
class SeekPoint:
    compressed_bit: int
    decompressed_byte: int
    window: Optional[bytes]  # None => empty/unknown (stream start needs none)
    flags: int = 0

    @property
    def is_stream_start(self) -> bool:
        return bool(self.flags & FLAG_STREAM_START)


class GzipIndex:
    """Sorted, thread-safe collection of seek points.

    ``codec_tag`` names the codec whose chunk semantics the points encode
    (see ``core.codec``). It is serialized in the v2 header; legacy v1
    blobs carry no tag and import as ``"deflate"``.
    """

    def __init__(self, codec_tag: str = "deflate") -> None:
        self._points: List[SeekPoint] = []
        self._dec_offsets: List[int] = []  # parallel array for bisect
        self._lock = threading.RLock()
        self.finalized = False
        self.decompressed_size: Optional[int] = None
        self.compressed_size: Optional[int] = None
        self.codec_tag = codec_tag
        #: First-pass observations recorded by the reader (chunk counts,
        #: marker-mode chunks, fixed-only chunks, interior split points).
        #: Purely in-memory — never serialized; ``Codec.seek_hostility``
        #: reads them to score how seek-hostile the archive proved to be.
        #: An imported index has no observations and always scores 0.0.
        self.observations: dict = {}

    # -- construction -------------------------------------------------------

    def add_point(self, point: SeekPoint) -> None:
        with self._lock:
            if self._points and point.decompressed_byte <= self._dec_offsets[-1]:
                if point.decompressed_byte == self._dec_offsets[-1] and (
                    self._points[-1].compressed_bit == point.compressed_bit
                ):
                    return  # idempotent re-add
                if point.compressed_bit <= self._points[-1].compressed_bit:
                    return  # already covered
            self._points.append(point)
            self._dec_offsets.append(point.decompressed_byte)

    def finalize(self, decompressed_size: int, compressed_size: int) -> None:
        with self._lock:
            self.decompressed_size = decompressed_size
            self.compressed_size = compressed_size
            self.finalized = True

    # -- queries ------------------------------------------------------------

    def __len__(self) -> int:
        with self._lock:
            return len(self._points)

    def points(self) -> List[SeekPoint]:
        with self._lock:
            return list(self._points)

    def point_at(self, i: int) -> SeekPoint:
        with self._lock:
            return self._points[i]

    def covered_until(self) -> int:
        """Largest decompressed offset with a seek point at/before it."""
        with self._lock:
            return self._dec_offsets[-1] if self._points else 0

    def find(self, decompressed_offset: int) -> Optional[int]:
        """Index of the last seek point at or before ``decompressed_offset``."""
        with self._lock:
            i = bisect_right(self._dec_offsets, decompressed_offset) - 1
            return i if i >= 0 else None

    def chunk_output_size(self, i: int) -> Optional[int]:
        """Decompressed size of index chunk i (None for the open last chunk)."""
        with self._lock:
            if i + 1 < len(self._points):
                return self._dec_offsets[i + 1] - self._dec_offsets[i]
            if self.finalized and self.decompressed_size is not None:
                return self.decompressed_size - self._dec_offsets[i]
            return None

    # -- import/export ------------------------------------------------------

    def export_file(self, dest: Union[str, BinaryIO]) -> None:
        """Binary format: magic, JSON header, zlib-compressed windows."""
        own = isinstance(dest, str)
        f: BinaryIO = open(dest, "wb") if own else dest  # type: ignore[assignment]
        try:
            with self._lock:
                meta = {
                    "finalized": self.finalized,
                    "decompressed_size": self.decompressed_size,
                    "compressed_size": self.compressed_size,
                    "n_points": len(self._points),
                    "codec": self.codec_tag,
                }
                blob = json.dumps(meta).encode()
                f.write(_MAGIC)
                f.write(struct.pack("<I", len(blob)))
                f.write(blob)
                for p in self._points:
                    wz = zlib.compress(p.window or b"", 6)
                    f.write(struct.pack("<QQII", p.compressed_bit, p.decompressed_byte, p.flags, len(wz)))
                    f.write(wz)
        finally:
            if own:
                f.close()

    @classmethod
    def import_file(cls, src: Union[str, BinaryIO]) -> "GzipIndex":
        own = isinstance(src, str)
        f: BinaryIO = open(src, "rb") if own else src  # type: ignore[assignment]
        try:
            magic = f.read(len(_MAGIC))
            if magic not in (_MAGIC_V1, _MAGIC_V2):
                raise IndexError_("bad index magic")
            (blob_len,) = struct.unpack("<I", f.read(4))
            meta = json.loads(f.read(blob_len).decode())
            # v1 predates codec tags; every v1 index was built by the
            # deflate machinery (including BGZF files — deflate-compatible).
            idx = cls(codec_tag=meta.get("codec", "deflate"))
            for _ in range(meta["n_points"]):
                cb, db, flags, wlen = struct.unpack("<QQII", f.read(24))
                wz = f.read(wlen)
                window = zlib.decompress(wz) if wlen else b""
                idx.add_point(SeekPoint(cb, db, window, flags))
            if meta["finalized"]:
                idx.finalize(meta["decompressed_size"], meta["compressed_size"])
            return idx
        finally:
            if own:
                f.close()

    def to_bytes(self) -> bytes:
        buf = io.BytesIO()
        self.export_file(buf)
        return buf.getvalue()

    @classmethod
    def from_bytes(cls, data: bytes) -> "GzipIndex":
        return cls.import_file(io.BytesIO(data))
