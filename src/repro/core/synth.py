"""Synthetic gzip-variant compressors (test + benchmark data generation).

The paper evaluates decompression across files produced by gzip, pigz,
bgzip, and igzip at various levels (Table 3) — each tool produces a
structurally different gzip file. This module reproduces those structures
with zlib so benchmarks and tests can exercise every code path offline:

  * ``gzip_compress``        — single member, dynamic blocks (GNU gzip).
  * ``pigz_like_compress``   — independent deflate spans joined by empty
    stored (sync-flush) blocks, one member — pigz's byte-alignment
    workaround (paper §5).
  * ``multistream_gzip``     — concatenated gzip members (bgzip without
    metadata / concatenated .gz files).
  * ``bgzf_compress``        — Blocked GNU Zip Format: fixed-size members
    with the BC extra field carrying the compressed size (paper §3.4.4).
  * ``fixed_only_compress``  — every block uses fixed Huffman codes
    (zlib Z_FIXED): the block finder cannot find any block, so parallel
    decompression degrades to sequential — the igzip -0 analogue (§4.8).
  * ``stored_only_compress`` — level-0 stored blocks (bgzip -0 analogue:
    decompression is a memcpy via the NCB fast path).
"""

from __future__ import annotations

import struct
import zlib
from typing import Iterable, List

_GZIP_HEADER = b"\x1f\x8b\x08\x00\x00\x00\x00\x00\x00\xff"


def _gzip_member(raw_deflate: bytes, data: bytes) -> bytes:
    footer = struct.pack("<II", zlib.crc32(data) & 0xFFFFFFFF, len(data) & 0xFFFFFFFF)
    return _GZIP_HEADER + raw_deflate + footer


def gzip_compress(data: bytes, level: int = 6) -> bytes:
    c = zlib.compressobj(level, zlib.DEFLATED, -15)
    raw = c.compress(data) + c.flush(zlib.Z_FINISH)
    return _gzip_member(raw, data)


def pigz_like_compress(data: bytes, level: int = 6, block_size: int = 128 << 10) -> bytes:
    """Independent deflate spans + empty stored blocks, one gzip member."""
    parts: List[bytes] = []
    n = len(data)
    for off in range(0, max(n, 1), block_size):
        block = data[off : off + block_size]
        last = off + block_size >= n
        c = zlib.compressobj(level, zlib.DEFLATED, -15)
        body = c.compress(block)
        body += c.flush(zlib.Z_FINISH if last else zlib.Z_FULL_FLUSH)
        parts.append(body)
    return _gzip_member(b"".join(parts), data)


def multistream_gzip(data: bytes, level: int = 6, stream_size: int = 256 << 10) -> bytes:
    parts: List[bytes] = []
    for off in range(0, max(len(data), 1), stream_size):
        parts.append(gzip_compress(data[off : off + stream_size], level))
    return b"".join(parts)


#: BGZF EOF marker: empty member (fixed canonical bytes from the spec).
BGZF_EOF = bytes.fromhex(
    "1f8b08040000000000ff0600424302001b0003000000000000000000"
)


def _bgzf_member(block: bytes, level: int) -> bytes:
    """One BGZF member: gzip header with the 'BC' subfield = member size."""
    c = zlib.compressobj(level, zlib.DEFLATED, -15)
    raw = c.compress(block) + c.flush(zlib.Z_FINISH)
    # header: magic, CM, FLG=FEXTRA, mtime, XFL, OS, XLEN=6, BC subfield
    xtra = b"BC" + struct.pack("<HH", 2, 0)  # BSIZE patched below
    header = b"\x1f\x8b\x08\x04\x00\x00\x00\x00\x00\xff" + struct.pack("<H", 6) + xtra
    footer = struct.pack("<II", zlib.crc32(block) & 0xFFFFFFFF, len(block) & 0xFFFFFFFF)
    member = bytearray(header + raw + footer)
    bsize = len(member) - 1  # BSIZE = total block size minus 1
    member[16:18] = struct.pack("<H", bsize)
    return bytes(member)


def bgzf_compress(data: bytes, level: int = 6, block_size: int = 0xFF00) -> bytes:
    """BGZF: gzip members with the 'BC' extra subfield = total member size."""
    out: List[bytes] = []
    for off in range(0, max(len(data), 1), block_size):
        out.append(_bgzf_member(data[off : off + block_size], level))
    out.append(BGZF_EOF)
    return b"".join(out)


class BgzfStreamWriter:
    """Incremental BGZF writer for the transcode pipeline.

    Feed decompressed bytes in arbitrary-size pieces via :meth:`write`;
    whole members are emitted to ``sink`` (any object with a
    ``write(bytes)`` method) as soon as a block's worth accumulates, so
    memory stays O(block_size) no matter the archive size. :meth:`finish`
    flushes the final partial member and appends the canonical EOF marker.
    Byte layout is identical to :func:`bgzf_compress`.
    """

    def __init__(self, sink, level: int = 6, block_size: int = 0xFF00):
        self._sink = sink
        self._level = level
        self._block_size = block_size
        self._buf = bytearray()
        self._finished = False
        self.bytes_in = 0
        self.bytes_out = 0
        self.members = 0

    def write(self, data: bytes) -> None:
        if self._finished:
            raise ValueError("write after finish")
        self._buf += data
        self.bytes_in += len(data)
        while len(self._buf) >= self._block_size:
            self._emit(bytes(self._buf[: self._block_size]))
            del self._buf[: self._block_size]

    def _emit(self, block: bytes) -> None:
        member = _bgzf_member(block, self._level)
        self._sink.write(member)
        self.bytes_out += len(member)
        self.members += 1

    def finish(self) -> None:
        if self._finished:
            return
        self._finished = True
        if self._buf or self.members == 0:
            self._emit(bytes(self._buf))
            self._buf.clear()
        self._sink.write(BGZF_EOF)
        self.bytes_out += len(BGZF_EOF)


def fixed_only_compress(data: bytes, level: int = 6) -> bytes:
    """Every block uses fixed Huffman codes: finder-invisible (igzip -0 case)."""
    c = zlib.compressobj(level, zlib.DEFLATED, -15, 9, zlib.Z_FIXED)
    raw = c.compress(data) + c.flush(zlib.Z_FINISH)
    return _gzip_member(raw, data)


def stored_only_compress(data: bytes) -> bytes:
    """Level 0: all Non-Compressed blocks (bgzip -0 analogue)."""
    c = zlib.compressobj(0, zlib.DEFLATED, -15)
    raw = c.compress(data) + c.flush(zlib.Z_FINISH)
    return _gzip_member(raw, data)


def zstd_seekable_compress(data: bytes, level: int = 3, frame_size: int = 128 << 10) -> bytes:
    """Zstd seekable format: independent frames + the seek-table footer.

    The footer is the final skippable frame (magic 0x184D2A5E) holding one
    ``(compressed_size, decompressed_size)`` u32 pair per frame, then
    ``(frame_count, descriptor, 0x8F92EAB1)``. Needs a zstd library for the
    frame bodies (``core.codec.have_zstd``) — raises RuntimeError without
    one, so callers gate on availability rather than silently degrading.
    """
    import io

    sink = io.BytesIO()
    writer = ZstdSeekableStreamWriter(sink, level, frame_size)
    for off in range(0, max(len(data), 1), frame_size):
        writer.write(data[off : off + frame_size])
        writer.flush_frame()  # frame boundaries exactly at frame_size
    writer.finish()
    return sink.getvalue()


class ZstdSeekableStreamWriter:
    """Incremental zstd-seekable writer (transcode pipeline counterpart of
    :class:`BgzfStreamWriter`).

    Buffers decompressed input up to ``frame_size``, emits each chunk as an
    independent zstd frame, and :meth:`finish` appends the seek-table
    skippable frame (magic 0x184D2A5E, 8-byte entries, no checksums) that
    ``core.codec.parse_zstd_seek_table`` reads back. Needs a zstd library
    (``core.codec.have_zstd``) — raises RuntimeError without one.
    """

    def __init__(self, sink, level: int = 3, frame_size: int = 128 << 10):
        from .codec import zstd_backend

        self._backend = zstd_backend()
        if self._backend is None:
            raise RuntimeError("ZstdSeekableStreamWriter needs a zstd library")
        self._sink = sink
        self._level = level
        self._frame_size = frame_size
        self._buf = bytearray()
        self._entries: List[bytes] = []
        self._finished = False
        self.bytes_in = 0
        self.bytes_out = 0

    @property
    def members(self) -> int:
        return len(self._entries)

    def write(self, data: bytes) -> None:
        if self._finished:
            raise ValueError("write after finish")
        self._buf += data
        self.bytes_in += len(data)
        while len(self._buf) >= self._frame_size:
            self._emit(bytes(self._buf[: self._frame_size]))
            del self._buf[: self._frame_size]

    def flush_frame(self) -> None:
        """Force a frame boundary at the current buffered position."""
        if self._buf:
            self._emit(bytes(self._buf))
            self._buf.clear()

    def _emit(self, block: bytes) -> None:
        frame = self._backend.compress(block, self._level)
        self._sink.write(frame)
        self.bytes_out += len(frame)
        self._entries.append(struct.pack("<II", len(frame), len(block)))

    def finish(self) -> None:
        if self._finished:
            return
        self._finished = True
        if self._buf or not self._entries:
            self._emit(bytes(self._buf))
            self._buf.clear()
        table = b"".join(self._entries) + struct.pack(
            "<IBI", len(self._entries), 0, 0x8F92EAB1
        )
        skippable = struct.pack("<II", 0x184D2A5E, len(table)) + table
        self._sink.write(skippable)
        self.bytes_out += len(skippable)


COMPRESSORS = {
    "gzip-1": lambda d: gzip_compress(d, 1),
    "gzip-6": lambda d: gzip_compress(d, 6),
    "gzip-9": lambda d: gzip_compress(d, 9),
    "pigz-like-6": lambda d: pigz_like_compress(d, 6),
    "multistream-6": lambda d: multistream_gzip(d, 6),
    "bgzf-6": lambda d: bgzf_compress(d, 6),
    "bgzf-0": lambda d: bgzf_compress(d, 0),
    "fixed-only-6": lambda d: fixed_only_compress(d, 6),
    "stored-only": stored_only_compress,
}
