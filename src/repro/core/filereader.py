"""FileReader abstraction (paper §3, Fig 5).

Rapidgzip abstracts file access behind a ``FileReader`` interface so the same
decompression machinery can serve regular files, in-memory buffers, and Python
file-like objects (the paper uses this for recursive access to gzip-in-gzip).

``SharedFileReader`` is the thread-safe variant used by the parallel chunk
fetcher: every read is a *stateless* positioned read (POSIX ``pread`` semantics,
paper §4.2 / Fig 8) so worker threads never contend on a shared file position.
"""

from __future__ import annotations

import io
import os
import threading
from typing import Optional, Union


def check_pread_args(offset: int, size: int) -> None:
    """Shared argument contract for every ``pread`` implementation.

    A negative offset must raise rather than fall through to Python slicing
    (which would silently serve bytes from the *end* of an in-memory buffer)
    or to ``os.pread``/HTTP ranges (which fail with backend-specific errors).
    All backends agree: negative offset or size -> ValueError; reads at or
    past EOF -> b""; reads straddling EOF -> short result.
    """
    if offset < 0:
        raise ValueError("pread offset must be non-negative, got %d" % offset)
    if size < 0:
        raise ValueError("pread size must be non-negative, got %d" % size)


class FileReader:
    """Stateless positioned-read interface over a byte source."""

    def size(self) -> int:
        raise NotImplementedError

    def pread(self, offset: int, size: int) -> bytes:
        """Read up to ``size`` bytes at absolute ``offset`` (thread-safe).

        Contract (enforced by ``check_pread_args`` + the backend): negative
        ``offset``/``size`` raise ValueError; ``offset >= size()`` returns
        b""; a read straddling EOF returns the short tail; a short read from
        the underlying source never silently truncates mid-file.
        """
        raise NotImplementedError

    def identity(self) -> Optional[str]:
        """Cheap stable identity string for index caching, or None.

        Backends whose content identity is knowable without reading data
        (e.g. a remote object's URL + ETag + size) return it here so
        ``service.index_store.file_identity`` can key warm seek-indexes
        without downloading head/tail bytes.
        """
        return None

    def view(self) -> Optional[memoryview]:
        """Zero-copy view of the whole source, or None when unavailable.

        In-memory sources return a read-only ``memoryview`` so the chunk
        fetcher can scan without copying; file- and network-backed readers
        return None and are served via ``pread``. Public so callers never
        need to sniff concrete reader types for the fast path — a remote
        backend that cannot offer a view simply inherits this default.
        """
        return None

    def close(self) -> None:  # pragma: no cover - trivial
        pass

    def __enter__(self) -> "FileReader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class BytesFileReader(FileReader):
    """In-memory byte buffer source."""

    def __init__(self, data: Union[bytes, bytearray, memoryview]):
        self._data = bytes(data)

    def size(self) -> int:
        return len(self._data)

    def pread(self, offset: int, size: int) -> bytes:
        check_pread_args(offset, size)
        if offset >= len(self._data):
            return b""
        return self._data[offset : offset + size]

    def view(self) -> Optional[memoryview]:
        return memoryview(self._data)


class SharedFileReader(FileReader):
    """Thread-safe reader over a path using ``os.pread``.

    Mirrors the paper's SharedFileReader: many threads issue positioned reads
    against one file descriptor in parallel (Fig 8 benchmark).
    """

    def __init__(self, path: Union[str, os.PathLike]):
        self._path = os.fspath(path)
        self._fd = os.open(self._path, os.O_RDONLY)
        self._size = os.fstat(self._fd).st_size
        self._closed = False

    def size(self) -> int:
        return self._size

    def pread(self, offset: int, size: int) -> bytes:
        check_pread_args(offset, size)
        if offset >= self._size or size == 0:
            return b""
        out = []
        remaining = min(size, self._size - offset)
        while remaining > 0:
            chunk = os.pread(self._fd, remaining, offset)
            if not chunk:
                break
            out.append(chunk)
            offset += len(chunk)
            remaining -= len(chunk)
        return b"".join(out)

    def close(self) -> None:
        if not self._closed:
            os.close(self._fd)
            self._closed = True


class PythonFileReader(FileReader):
    """Adapter for arbitrary Python file-like objects (seek/read).

    File-like objects have a single cursor, so positioned reads are serialized
    behind a lock — this is the abstraction that lets rapidgzip-JAX decompress
    e.g. a gzip stream stored inside another ParallelGzipReader (recursive
    gzip-in-gzip access, paper §3).
    """

    def __init__(self, fileobj, *, close_fileobj: bool = False):
        if not (hasattr(fileobj, "read") and hasattr(fileobj, "seek")):
            raise TypeError("fileobj must support read() and seek()")
        self._f = fileobj
        self._close_fileobj = close_fileobj
        self._closed = False
        self._lock = threading.Lock()
        with self._lock:
            pos = self._f.tell()
            self._f.seek(0, io.SEEK_END)
            self._size = self._f.tell()
            self._f.seek(pos)

    def size(self) -> int:
        return self._size

    def pread(self, offset: int, size: int) -> bytes:
        check_pread_args(offset, size)
        with self._lock:
            self._f.seek(offset)
            # read(n) may legally return fewer than n bytes before EOF
            # (sockets, pipes, BufferedReader subclasses); loop so a short
            # read never silently truncates a chunk mid-file — a truncated
            # buffer poisons trial decompression downstream.
            out = []
            remaining = size
            while remaining > 0:
                chunk = self._f.read(remaining)
                if not chunk:
                    break
                out.append(chunk)
                remaining -= len(chunk)
            return b"".join(out)

    def close(self) -> None:
        if self._close_fileobj and not self._closed:
            self._f.close()
        self._closed = True


def open_file_reader(
    source: Union[str, os.PathLike, bytes, bytearray, memoryview, FileReader, object],
) -> FileReader:
    """Open any supported source as a FileReader."""
    if isinstance(source, FileReader):
        return source
    if isinstance(source, (bytes, bytearray, memoryview)):
        return BytesFileReader(source)
    if isinstance(source, str) and source.startswith(("http://", "https://")):
        from .remote import RemoteFileReader  # local import: avoids cycle

        return RemoteFileReader(source)
    if isinstance(source, (str, os.PathLike)):
        return SharedFileReader(source)
    return PythonFileReader(source)
