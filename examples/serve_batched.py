"""Batched serving demo: prefill a batch of prompts, then greedy-decode with
sharded KV caches (the ``decode_32k``-style serve_step at toy scale).

    PYTHONPATH=src python examples/serve_batched.py --arch gemma-2b
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import all_configs, smoke_config
from repro.distributed import default_rules
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.serve import make_serve_steps, prefill_to_decode_caches


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=sorted(all_configs()))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = smoke_config(all_configs()[args.arch])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = make_host_mesh()
    rules = default_rules(mesh)

    B, P, N = args.batch, args.prompt_len, args.new_tokens
    max_len = P + N + (cfg.vision_tokens if cfg.family == "vlm" else 0)
    prefill_fn, decode_fn, _, _ = make_serve_steps(model, mesh, rules, batch=B, max_len=max_len)

    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, P), dtype=np.int32))}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_frames, cfg.d_model)).astype(np.float32)
        ).astype(jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.vision_tokens, cfg.d_model)).astype(np.float32)
        ).astype(jnp.bfloat16)

    t0 = time.perf_counter()
    logits, pc = prefill_fn(params, batch)
    prefix = cfg.vision_tokens if cfg.family == "vlm" else 0
    caches = prefill_to_decode_caches(cfg, model, pc, B, max_len, P + prefix)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    t_prefill = time.perf_counter() - t0
    print(f"prefill {B}x{P}: {t_prefill*1e3:.0f} ms")

    generated = [tok]
    t0 = time.perf_counter()
    for t in range(N - 1):
        tok, _, caches = decode_fn(params, tok, caches, jnp.int32(P + prefix + t))
        generated.append(tok)
    dt = time.perf_counter() - t0
    out = np.concatenate([np.asarray(g) for g in generated], axis=1)
    print(f"decode {N-1} steps: {dt*1e3:.0f} ms "
          f"({B*(N-1)/dt:.1f} tok/s batched, greedy)")
    for b in range(B):
        print(f"  seq {b}: {out[b][:16].tolist()}...")


if __name__ == "__main__":
    main()
