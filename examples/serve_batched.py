"""Batched serving demo: prefill a batch of prompts, then greedy-decode with
sharded KV caches (the ``decode_32k``-style serve_step at toy scale) — while
the same process serves corpus range-reads out of gzip shards through the
archive service (retrieval-style traffic: each decoded sequence fetches a
context document by decompressed offset).

    PYTHONPATH=src python examples/serve_batched.py --arch gemma-2b
    PYTHONPATH=src python examples/serve_batched.py --no-corpus   # model only
"""

import argparse
import gzip as _gzip
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import all_configs, smoke_config
from repro.distributed import default_rules
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.serve import make_serve_steps, prefill_to_decode_caches
from repro.service import ArchiveServer, IndexStore, format_summary


def make_corpus_service(tmpdir: str, *, n_shards: int = 3, shard_mb: float = 1.0):
    """Gzip corpus shards + an ArchiveServer over them (warm-capable)."""
    rng = np.random.default_rng(7)
    words = [b"the", b"quick", b"brown", b"fox", b"rapidgzip", b"serve",
             b"retrieval", b"document", b"context", b"window"]
    paths, sizes = [], []
    for s in range(n_shards):
        n = int(shard_mb * (1 << 20))
        doc = b" ".join(words[i] for i in rng.integers(0, len(words), n // 6))[:n]
        path = os.path.join(tmpdir, f"corpus-{s:02d}.txt.gz")
        with open(path, "wb") as f:
            f.write(_gzip.compress(doc, 6))
        paths.append(path)
        sizes.append(len(doc))
    server = ArchiveServer(
        max_workers=4,
        cache_budget_bytes=8 << 20,  # far below n_shards x per-reader maxima
        index_store=IndexStore(os.path.join(tmpdir, "indexes")),
        chunk_size=256 << 10,
    )
    handles = [server.open(p, tenant="serve") for p in paths]
    return server, handles, sizes


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=sorted(all_configs()))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--no-corpus", action="store_true",
                    help="skip the archive-service corpus demo")
    ap.add_argument("--corpus-shards", type=int, default=3)
    ap.add_argument("--corpus-mb", type=float, default=1.0)
    args = ap.parse_args()

    cfg = smoke_config(all_configs()[args.arch])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = make_host_mesh()
    rules = default_rules(mesh)

    B, P, N = args.batch, args.prompt_len, args.new_tokens
    max_len = P + N + (cfg.vision_tokens if cfg.family == "vlm" else 0)
    prefill_fn, decode_fn, _, _ = make_serve_steps(model, mesh, rules, batch=B, max_len=max_len)

    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, P), dtype=np.int32))}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_frames, cfg.d_model)).astype(np.float32)
        ).astype(jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.vision_tokens, cfg.d_model)).astype(np.float32)
        ).astype(jnp.bfloat16)

    t0 = time.perf_counter()
    logits, pc = prefill_fn(params, batch)
    prefix = cfg.vision_tokens if cfg.family == "vlm" else 0
    caches = prefill_to_decode_caches(cfg, model, pc, B, max_len, P + prefix)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    t_prefill = time.perf_counter() - t0
    print(f"prefill {B}x{P}: {t_prefill*1e3:.0f} ms")

    corpus = None
    corpus_dir = None
    if not args.no_corpus:
        corpus_dir = tempfile.TemporaryDirectory(prefix="serve_corpus_")
        corpus = make_corpus_service(
            corpus_dir.name, n_shards=args.corpus_shards, shard_mb=args.corpus_mb
        )

    generated = [tok]
    doc_bytes = 0
    t0 = time.perf_counter()
    for t in range(N - 1):
        tok, _, caches = decode_fn(params, tok, caches, jnp.int32(P + prefix + t))
        generated.append(tok)
        if corpus is not None:
            # Retrieval-style traffic interleaved with decode: each sequence
            # pulls a context snippet addressed by decompressed offset.
            server, handles, sizes = corpus
            for b in range(B):
                shard = (b + t) % len(handles)
                off = int(np.asarray(tok)[b, 0]) * 1009 % max(1, sizes[shard] - 512)
                doc_bytes += len(server.read_range(handles[shard], off, 512))
    dt = time.perf_counter() - t0
    out = np.concatenate([np.asarray(g) for g in generated], axis=1)
    print(f"decode {N-1} steps: {dt*1e3:.0f} ms "
          f"({B*(N-1)/dt:.1f} tok/s batched, greedy)")
    for b in range(B):
        print(f"  seq {b}: {out[b][:16].tolist()}...")

    if corpus is not None:
        server, handles, _ = corpus
        print(f"\ncorpus service: {doc_bytes/1e3:.0f} kB of context served "
              f"during decode, budget-shared across {len(handles)} shards")
        print(format_summary(server.metrics()))
        server.shutdown()
        corpus_dir.cleanup()


if __name__ == "__main__":
    main()
