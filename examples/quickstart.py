"""Quickstart: parallel gzip decompression, random access, and the seek index.

    PYTHONPATH=src python examples/quickstart.py
"""

import gzip
import io
import time

import numpy as np

from repro.core import GzipIndex, ParallelGzipReader


def main() -> None:
    # -- make a multi-member gzip file -------------------------------------
    rng = np.random.default_rng(7)
    words = [b"lorem", b"ipsum", b"dolor", b"sit", b"amet", b"rapidgzip"]
    doc = b" ".join(words[i] for i in rng.integers(0, len(words), 800_000))
    compressed = gzip.compress(doc[: len(doc) // 2], 6) + gzip.compress(doc[len(doc) // 2 :], 9)
    print(f"corpus: {len(doc):,} bytes -> {len(compressed):,} compressed "
          f"(ratio {len(doc)/len(compressed):.2f}, 2 gzip members)")

    # -- 1. parallel decompression (speculative two-stage + prefetch) ------
    t0 = time.perf_counter()
    with ParallelGzipReader(compressed, parallelization=4, chunk_size=256 << 10) as reader:
        out = reader.read()
        assert out == doc
        stats = reader.stats()["fetcher"]
        print(f"first pass: {time.perf_counter()-t0:.2f}s | speculative tasks: "
              f"{stats['nominal_tasks']}, exact: {stats['exact_tasks']}, "
              f"false positives absorbed: {stats['false_positive_starts']}, "
              f"marker chunks: {stats['chunks_with_markers']}")

        # -- 2. export the seek index (built on the fly) -------------------
        buf = io.BytesIO()
        reader.export_index(buf)
        print(f"seek index: {len(reader.index)} points, {len(buf.getvalue()):,} bytes")

    # -- 3. O(1) random access through the index ---------------------------
    index = GzipIndex.from_bytes(buf.getvalue())
    with ParallelGzipReader(compressed, parallelization=4, index=index) as reader:
        t0 = time.perf_counter()
        reader.seek(700_000)
        sample = reader.read(64)
        dt = time.perf_counter() - t0
        assert sample == doc[700_000:700_064]
        print(f"random access at offset 700k: {dt*1e3:.1f} ms -> {sample[:32]!r}...")
        print(f"zlib delegations (index fast path): "
              f"{reader.stats()['fetcher']['zlib_delegations']}")


if __name__ == "__main__":
    main()
