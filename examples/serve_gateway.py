"""Gateway quickstart: the archive service on the wire.

Spins up a loopback `GatewayServer` over a small generated corpus and walks
the whole wire surface: authenticated opens, range reads (the paper's O(range)
random access, now per HTTP request), chunked streaming, a gateway-backed
training dataset, tenant flood -> 429 backpressure, and a mid-stream client
disconnect whose speculation is cancelled end to end (watch the scheduler's
``cancelled`` counter).

    PYTHONPATH=src python examples/serve_gateway.py
    PYTHONPATH=src python examples/serve_gateway.py --trace
        # ... writes a Chrome trace-event JSON on exit; load it in
        # Perfetto / chrome://tracing to see every demo request's spans
    PYTHONPATH=src python examples/serve_gateway.py --port 8080 --keep
        # ... then from another shell:
        # curl -H 'Authorization: Bearer demo-token' \
        #      -H 'Range: bytes=1000-1999' \
        #      http://127.0.0.1:8080/v1/archives/f1/bytes
        # curl http://127.0.0.1:8080/metrics   # Prometheus exposition
"""

import argparse
import gzip
import http.client
import os
import socket
import tempfile
import time

import numpy as np

from repro.data.pipeline import GzipCorpusDataset
from repro.service import format_summary
from repro.service.gateway import GatewayClient, GatewayServer, TenantAdmission
from repro.service.gateway.admission import TenantLimit


def make_corpus(tmpdir: str, n_shards: int = 2, shard_kb: int = 512):
    rng = np.random.default_rng(11)
    words = [b"the", b"gateway", b"serves", b"decompressed", b"bytes",
             b"over", b"plain", b"http", b"range", b"requests"]
    paths = []
    for s in range(n_shards):
        n = shard_kb << 10
        doc = b" ".join(words[i] for i in rng.integers(0, len(words), n // 6))[:n]
        path = os.path.join(tmpdir, f"corpus-{s:02d}.txt.gz")
        with open(path, "wb") as f:
            f.write(gzip.compress(doc, 6))
        paths.append(path)
    return paths


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--port", type=int, default=0, help="0 = ephemeral")
    ap.add_argument("--keep", action="store_true",
                    help="keep serving until Ctrl-C (for curl exploration)")
    ap.add_argument("--trace", action="store_true",
                    help="enable request tracing; dump a Chrome trace-event "
                         "JSON (open in Perfetto) on exit")
    args = ap.parse_args()

    if args.trace:
        from repro import obs
        obs.enable_tracing()

    tmpdir = tempfile.mkdtemp(prefix="gateway_demo_")
    paths = make_corpus(tmpdir)

    admission = TenantAdmission(
        tokens={"demo-token": "demo", "noisy-token": "noisy"},
        default_tenant=None,                      # auth required
        limits={"noisy": TenantLimit(max_in_flight=1, max_queued=1)},
        quanta={"demo": 2.0},                     # demo pays for 2x quantum
        retry_after=0.5,
    )
    with GatewayServer(
        port=args.port,
        admission=admission,
        open_roots=[tmpdir],                      # jail opens to the corpus
        cache_budget_bytes=16 << 20,
        max_workers=4,
        chunk_size=128 << 10,
        stream_span=128 << 10,
    ) as gw:
        print(f"gateway listening on {gw.url}")

        # -- FileReader over the wire ------------------------------------
        client = GatewayClient(gw.url, source=paths[0], token="demo-token")
        print(f"opened {paths[0]} as handle {client.handle}, "
              f"decompressed size {client.size()} bytes, etag {client.etag}")
        page = client.pread(1000, 200)
        print(f"pread(1000, 200) -> {page[:40]!r}...")
        streamed = sum(len(chunk) for chunk in client.stream())
        print(f"chunked full stream -> {streamed} bytes")

        # -- a training dataset pointed at the gateway --------------------
        ds = GzipCorpusDataset(
            ["gateway+" + gw.bytes_url(client.handle)],
            seq_len=128, batch_size=2, loop=False,
            remote_options={"headers": {"Authorization": "Bearer demo-token"}},
        )
        batch = ds.next_batch()
        print(f"gateway-backed dataset batch: {batch['tokens'].shape}")
        ds.close()

        # -- tenant flood: bounded, answered with 429 ---------------------
        host, port = gw.url[len("http://"):].rsplit(":", 1)
        noisy = GatewayClient(gw.url, source=paths[1], token="noisy-token")
        codes = []
        import threading

        def flood():
            conn = http.client.HTTPConnection(host, int(port), timeout=10)
            try:
                conn.request("GET", f"/v1/archives/{noisy.handle}/bytes",
                             headers={"Authorization": "Bearer noisy-token"})
                resp = conn.getresponse()
                resp.read()
                codes.append(resp.status)
            finally:
                conn.close()

        threads = [threading.Thread(target=flood) for _ in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        print(f"flooding tenant saw statuses: {sorted(codes)} "
              f"(429 = admission backpressure, Retry-After set)")

        # -- mid-stream disconnect: cancelled end to end ------------------
        s = socket.create_connection((host, int(port)), timeout=10)
        s.sendall(b"GET /v1/archives/%s/bytes HTTP/1.1\r\nHost: demo\r\n"
                  b"Authorization: Bearer demo-token\r\n\r\n"
                  % client.handle.encode())
        s.recv(2048)  # first chunk of the stream
        s.close()     # ... and we are gone
        time.sleep(0.3)

        print("\n--- gateway telemetry ---")
        print(format_summary(gw.metrics()))

        if args.keep:
            print("\nserving until Ctrl-C ...")
            try:
                while True:
                    time.sleep(3600)
            except KeyboardInterrupt:
                pass
        noisy.close()
        client.close()

    if args.trace:
        trace_path = os.path.join(tmpdir, "gateway_trace.json")
        trace = obs.dump_trace(trace_path)
        print(f"\nwrote {len(trace['traceEvents'])} trace events to "
              f"{trace_path} (load in Perfetto / chrome://tracing)")


if __name__ == "__main__":
    main()
