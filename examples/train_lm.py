"""End-to-end LM training on a gzip-compressed corpus.

Default settings train a ~20M-parameter granite-family model for 120 steps
on CPU in a few minutes; ``--full`` switches to a ~100M-parameter config
(use on real accelerators). Demonstrates the whole stack: parallel gzip
decompression -> tokenize/pack -> pjit train step -> checkpoint -> restore.

    PYTHONPATH=src python examples/train_lm.py
    PYTHONPATH=src python examples/train_lm.py --full --steps 300
"""

import argparse
import dataclasses
import glob
import os
import shutil
import tempfile

import jax
import numpy as np

from repro.checkpoint import latest_checkpoint, restore_checkpoint, save_checkpoint
from repro.configs import get_config, smoke_config
from repro.data import GzipCorpusDataset
from repro.distributed import default_rules
from repro.launch.mesh import make_host_mesh
from repro.launch.train import make_corpus
from repro.models import build_model
from repro.train import AdamWConfig, init_train_state, make_train_step


def model_config(full: bool):
    base = get_config("granite-3-2b")
    if not full:
        return dataclasses.replace(
            smoke_config(base), name="granite-demo-20m",
            n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024,
            vocab_size=512,
        )
    # ~100M-parameter config (12L x 768)
    return dataclasses.replace(
        base, name="granite-demo-100m",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_ff=3072,
        vocab_size=32768, tie_embeddings=True, attn_q_chunk=256,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--resume-demo", action="store_true",
                    help="kill-and-restore mid-run to demo fault tolerance")
    args = ap.parse_args()

    cfg = model_config(args.full)
    model = build_model(cfg)
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(model.abstract()))
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params")

    corpus = os.path.join(tempfile.gettempdir(), "repro_corpus_demo")
    make_corpus(corpus, n_shards=2, shard_bytes=2 << 20)
    shards = sorted(glob.glob(os.path.join(corpus, "*.gz")))
    ds = GzipCorpusDataset(shards, seq_len=args.seq, batch_size=args.batch,
                           parallelization=4, chunk_size=256 << 10)

    mesh = make_host_mesh()
    rules = default_rules(mesh)
    params, opt = init_train_state(model, jax.random.PRNGKey(0))
    step_fn, _ = make_train_step(
        model, mesh, rules,
        AdamWConfig(peak_lr=3e-3, warmup_steps=10, total_steps=args.steps),
    )

    ckpt_dir = os.path.join(tempfile.gettempdir(), "repro_ckpt_demo")
    shutil.rmtree(ckpt_dir, ignore_errors=True)

    halfway = args.steps // 2
    losses = []
    for step in range(args.steps):
        batch = ds.next_batch()
        params, opt, metrics = step_fn(params, opt, batch)
        losses.append(float(metrics["loss"]))
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {losses[-1]:.4f}")
        if args.resume_demo and step == halfway:
            save_checkpoint(ckpt_dir, step + 1, {"params": params, "opt": opt,
                                                 "data": ds.state_dict()})
            print(f"--- simulating preemption at step {step+1}: "
                  f"restoring everything from checkpoint ---")
            params, opt = init_train_state(model, jax.random.PRNGKey(99))
            s, state = restore_checkpoint(latest_checkpoint(ckpt_dir),
                                          {"params": params, "opt": opt, "data": ds.state_dict()})
            params, opt = state["params"], state["opt"]
            ds.load_state_dict(state["data"])
            assert s == step + 1

    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'decreased' if losses[-1] < losses[0] else 'NOT decreased'})")
    ds.close()


if __name__ == "__main__":
    main()
