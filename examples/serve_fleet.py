"""Fleet quickstart: a sharded archive service across three gateway peers.

Spins up three loopback `GatewayServer` peers (each with its own
`ArchiveServer` + `IndexStore`, index fallbacks cross-wired) behind a
`FleetRouter`, then walks the fleet surface: rendezvous placement (each
archive lands on exactly one owner, every client agrees which), a mid-stream
owner kill with transparent exact-offset resume on the failover peer
(bit-identical bytes), membership ejection on the next probe sweep, and the
cross-node index exchange — a cold open on a peer that never saw the
archive imports the finalized seek index from whoever built it and does
zero speculative work.

    PYTHONPATH=src python examples/serve_fleet.py
"""

import gzip
import os
import tempfile
import time

import numpy as np

from repro.service import ArchiveServer, IndexStore, format_summary
from repro.service.fleet import FleetRouter, make_index_fallback
from repro.service.gateway import GatewayClient, GatewayServer


def make_corpus(tmpdir: str):
    """A few small shards plus one big one (big enough to stream through)."""
    rng = np.random.default_rng(23)
    words = [rng.bytes(3) * 2 for _ in range(64)]
    paths = {}
    for name, n_words in (("small-0", 40_000), ("small-1", 40_000),
                          ("big", 1_200_000)):
        data = b" ".join(words[int(i)] for i in rng.integers(0, 64, n_words))
        path = os.path.join(tmpdir, f"{name}.txt.gz")
        with open(path, "wb") as f:
            f.write(gzip.compress(data, 5))
        paths[name] = (path, data)
    return paths


def main() -> None:
    tmpdir = tempfile.mkdtemp(prefix="fleet_demo_")
    corpus = make_corpus(tmpdir)

    # -- three peers, each its own server + index store ---------------------
    stores, servers, gws = [], [], []
    for i in range(3):
        store = IndexStore(os.path.join(tmpdir, f"idx{i}"))
        srv = ArchiveServer(cache_budget_bytes=16 << 20, max_workers=2,
                            chunk_size=128 << 10, index_store=store)
        stores.append(store)
        servers.append(srv)
        gws.append(GatewayServer(srv, stream_span=64 << 10).start())
    urls = [gw.url for gw in gws]
    # cross-node index exchange: every store asks the *other* peers on a miss
    for i, store in enumerate(stores):
        store.set_remote_fallback(make_index_fallback(urls, exclude=[urls[i]]))

    with FleetRouter(urls, probe_interval=0.5, eject_after=1) as router:
        # -- placement: each archive has one owner, chosen by content key ---
        print("== placement ==")
        for name, (path, _) in corpus.items():
            key = router.key_for(path)
            print(f"  {name}: key {key[:12]}… -> owner {router.owner(key)}")

        # -- kill the owner mid-stream: the read does not notice -----------
        print("\n== failover: kill the owner mid-stream ==")
        path, data = corpus["big"]
        client = router.open(path)
        owner = client.peer
        got, n, killed = [], 0, False
        for chunk in client.stream(read_size=64 << 10):
            got.append(chunk)
            n += len(chunk)
            if not killed and n >= 1 << 20:
                killed = True
                print(f"  killing owner {owner} at byte {n:,} …")
                next(gw for gw in gws if gw.url == owner).close()
        assert b"".join(got) == data, "stream bytes diverged!"
        print(f"  stream finished on {client.peer}: {n:,} bytes, "
              f"bit-identical (failovers={client.stats['failovers']}, "
              f"resumed={client.stats['resumed_streams']})")
        client.close()  # persists the finalized index on the survivor

        # -- membership notices on the next sweep ---------------------------
        router.membership.probe_once()
        snap = router.membership.snapshot()
        print(f"  membership: {snap['alive']}/{snap['total']} peers alive")

        # -- index exchange: a cold open elsewhere is warm -------------------
        print("\n== index exchange: cold open on a fresh peer ==")
        third = next(u for u in urls
                     if u != owner and u != client.peer)
        t0 = time.time()
        g = GatewayClient(third, source=path)
        dt = time.time() - t0
        stat = g.stat()
        peer_metrics = next(gw for gw in gws if gw.url == third).metrics()
        print(f"  open on {third}: {dt*1e3:.1f}ms, "
              f"index_was_warm={stat['index_was_warm']}, "
              f"speculative tasks="
              f"{peer_metrics['fleet']['fetcher']['nominal_tasks']} "
              f"(index fetched from a peer: "
              f"{peer_metrics['index_store']['remote_hits']} hit)")
        g.close()

        # -- fleet telemetry -------------------------------------------------
        print("\n== fleet metrics ==")
        snapshot = peer_metrics
        snapshot.update(router.metrics())
        print(format_summary(snapshot))

    for gw in gws:
        try:
            gw.close()
        except Exception:  # noqa: BLE001 - the killed owner is already down
            pass
    for srv in servers:
        srv.shutdown()


if __name__ == "__main__":
    main()
